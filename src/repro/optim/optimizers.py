"""Pure-JAX optimizers (no optax in this container).

``Optimizer`` bundles init/apply plus the bucket-granular surface the
ParamBuckets API needs (DESIGN.md §6):

- ``slice_state(state, keys)`` / ``merge_state(state, keys, bucket_state)``
  slice and write back the optimizer state for one ``ParamBucket`` —
  optimizer state is a dict of params-shaped trees (sgd-momentum ``{"mu"}``,
  adamw ``{"m", "v"}``), so a bucket's slice is the bucket's top-level keys
  of every such tree.  This is what lets the layerwise (per-bucket
  non-instant) update path drive *stateful* optimizers, not just plain SGD.
- ``pre_apply`` is the optimizer's **global** gradient transform (adamw's
  global-norm clip) — the only part of an update that couples parameters
  across buckets.  ``apply_raw`` is ``apply`` minus ``pre_apply``: per-leaf
  arithmetic only, so applying it bucket-by-bucket is bit-identical to one
  whole-tree ``apply`` given pre-transformed gradients.  ``pre_apply is
  None`` means the optimizer has no global coupling and per-bucket updates
  can fire the moment each bucket's gradient is produced.

Moment dtype is configurable — bf16 moments halve optimizer-state HBM for
the 235B MoE config (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def slice_state(state: dict, keys) -> dict:
    """The bucket slice of an optimizer state: for every top-level moment
    tree (params-shaped), take the bucket's param keys."""
    return {k: {key: v[key] for key in keys} for k, v in state.items()}


def merge_state(state: dict, keys, bucket_state: dict) -> dict:
    """Write a bucket slice back into the full optimizer state."""
    del keys
    return {k: {**state[k], **bucket_state.get(k, {})} for k in state}


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    apply: Callable  # (params, grads, state, step) -> (new_params, new_state)
    #: global gradient transform (e.g. adamw's global-norm clip); None =
    #: no cross-bucket coupling, per-bucket updates may apply instantly
    pre_apply: Optional[Callable] = None
    #: ``apply`` minus ``pre_apply`` (defaults to ``apply``): strictly
    #: per-leaf, safe to call bucket-by-bucket
    apply_raw: Optional[Callable] = None

    def __post_init__(self):
        if self.apply_raw is None:
            object.__setattr__(self, "apply_raw", self.apply)

    # bucket-granular state access (module-level functions as methods so a
    # custom Optimizer can override them if its state is not params-shaped)
    slice_state = staticmethod(slice_state)
    merge_state = staticmethod(merge_state)


def sgd(lr_fn: Callable, momentum: float = 0.0,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def apply(params, grads, state, step):
        lr = lr_fn(step)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * (g.astype(jnp.float32)
                                      + weight_decay * p.astype(jnp.float32))
                              ).astype(p.dtype),
                params, grads)
            return new_params, state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu}

    return Optimizer(init, apply)


def adamw(lr_fn: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype: str = "float32",
          grad_clip: Optional[float] = 1.0) -> Optimizer:
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def pre_apply(grads):
        # the ONE globally-coupled piece of the update: the clip scale is a
        # function of the whole gradient tree's norm
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)) + 1e-12)
        scale = jnp.minimum(1.0, grad_clip / gn)
        return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    def apply_raw(params, grads, state, step):
        lr = lr_fn(step)
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - jnp.power(b1, step_f)
        bc2 = 1.0 - jnp.power(b2, step_f)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, m32.astype(mdt), v32.astype(mdt)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v}

    def apply(params, grads, state, step):
        if grad_clip is not None:
            grads = pre_apply(grads)
        return apply_raw(params, grads, state, step)

    return Optimizer(init, apply,
                     pre_apply=pre_apply if grad_clip is not None else None,
                     apply_raw=apply_raw)
