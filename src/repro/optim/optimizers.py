"""Pure-JAX optimizers (no optax in this container).

``Optimizer`` bundles init/apply.  Moment dtype is configurable — bf16
moments halve optimizer-state HBM for the 235B MoE config (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    apply: Callable  # (params, grads, state, step) -> (new_params, new_state)


def sgd(lr_fn: Callable, momentum: float = 0.0,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def apply(params, grads, state, step):
        lr = lr_fn(step)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * (g.astype(jnp.float32)
                                      + weight_decay * p.astype(jnp.float32))
                              ).astype(p.dtype),
                params, grads)
            return new_params, state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu}

    return Optimizer(init, apply)


def adamw(lr_fn: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          moment_dtype: str = "float32",
          grad_clip: Optional[float] = 1.0) -> Optimizer:
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def apply(params, grads, state, step):
        lr = lr_fn(step)
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        if grad_clip is not None:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)) + 1e-12)
            scale = jnp.minimum(1.0, grad_clip / gn)
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        bc1 = 1.0 - jnp.power(b1, step_f)
        bc2 = 1.0 - jnp.power(b2, step_f)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, m32.astype(mdt), v32.astype(mdt)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, apply)
