from repro.optim.optimizers import sgd, adamw, Optimizer  # noqa: F401
