"""Paper Table 2 'Large' CNN.

C20@4x4 -> P1 -> C60@5x5 -> P2 -> C100@6x6 -> P -> FC150 -> 10.
(29->26 conv, 26->26 pool1x1, 26->22 conv, 22->11 pool2, 11->6 conv, 6->3 pool)

NOTE: Table 2 lists the last pool as 3x3/"map size 2x2" but also 900 neurons
and 135,150 FC weights, which requires a 3x3x100 pool output.  We use a 2x2
pool (6->3) so the parameter count matches the paper's exactly (383,160).
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="chaos-large", family="cnn",
    cnn_layers=(
        ("conv", 20, 4),    # 29 -> 26
        ("pool", 1),        # 26 -> 26 (paper's 1x1 'pool')
        ("conv", 60, 5),    # 26 -> 22
        ("pool", 2),        # 22 -> 11
        ("conv", 100, 6),   # 11 -> 6
        ("pool", 2),        # 6 -> 3  (see NOTE above)
        ("fc", 150),
    ),
    cnn_input=(29, 29), n_classes=10,
    param_dtype="float32", lr_schedule="decay",
    scan_layers=False, remat=False,
)


def smoke_config() -> ArchConfig:
    return CONFIG
