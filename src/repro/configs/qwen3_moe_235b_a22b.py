"""qwen3-moe-235b-a22b: MoE 94L d_model=4096 64H (GQA kv=4) vocab=151936.

128 experts, top-8, per-expert d_ff=1536. [hf:Qwen/Qwen3-30B-A3B; hf]
bf16 optimizer moments (memory headroom on 16G v5e — see DESIGN.md §4).
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, moe_d_ff=1536,
    opt_moment_dtype="bfloat16",
    micro_batches=4,  # activation stacks / 4 -> fits 16G v5e (EXPERIMENTS.md)
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-235b-a22b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=96, vocab_size=512, qk_norm=True,
        n_experts=8, top_k=2, moe_d_ff=96, capacity_factor=4.0,
        scan_layers=False, remat=False,
    )
