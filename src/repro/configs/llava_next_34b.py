"""llava-next-34b: VLM, 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

anyres tiling -> the vision frontend is a STUB; ``input_specs`` provides
precomputed patch embeddings (n_patches x d_model) concatenated before the
text tokens.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab_size=64000, rope_theta=5e6,
    n_patches=1024,  # anyres grid (stubbed frontend)
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llava-next-34b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=512, n_patches=16,
        scan_layers=False, remat=False,
    )
