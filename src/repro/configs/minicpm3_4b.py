"""minicpm3-4b: dense 62L d_model=2560 40H d_ff=6400 vocab=73448 with MLA.

Multi-head Latent Attention (compressed KV cache). [hf:openbmb/MiniCPM3-4B; hf]
MLA ranks follow the published checkpoint: q_lora 768, kv_lora 256,
qk_nope 64, qk_rope 32, v_head 64.  Vocab padded 73448 -> 73472.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="mla",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_head=96,
    d_ff=6400, vocab_size=73448, rope_theta=1e4,
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b-smoke", family="mla",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=24,
        d_ff=128, vocab_size=256,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, scan_layers=False, remat=False,
    )
