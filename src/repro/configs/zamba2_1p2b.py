"""zamba2-1.2b: hybrid, 38L d_model=2048 d_ff=8192 ssm_state=64.

Mamba2 backbone + one SHARED attention block (32H, weights reused) inserted
every 6 layers.  [arXiv:2411.15242; hf]  Sub-quadratic -> runs long_500k.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=32000, ssm_state=64, ssm_conv=4, ssm_expand=2,
    attn_every=6,
    micro_batches=2,  # SSD intra-chunk tensors are seq*chunk-sized (§Perf)
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_conv=4, ssm_expand=2,
        attn_every=2, scan_layers=False, remat=False,
    )
