"""whisper-small: enc-dec, 12L(+12 enc) d_model=768 12H d_ff=3072 vocab=51865.

Conv audio frontend is a STUB — ``input_specs`` supplies precomputed frame
embeddings (enc_frames x d_model). [arXiv:2212.04356; unverified]
Vocab padded 51865 -> 51968.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_head=64, d_ff=3072, vocab_size=51865, enc_frames=1500,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=128, vocab_size=256, enc_frames=32,
        scan_layers=False, remat=False,
    )
