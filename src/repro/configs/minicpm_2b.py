"""minicpm-2b: dense 40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.

WSD learning-rate schedule; llama-like arch. [arXiv:2404.06395; hf]
Vocab padded 122753 -> 122880 for 16-way TP (see DESIGN.md §6).
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_head=64,
    d_ff=5760, vocab_size=122753, rope_theta=1e4,
    tie_embeddings=True, lr_schedule="wsd",
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="minicpm-2b-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=6, n_kv_heads=6, d_head=8,
        d_ff=96, vocab_size=256, tie_embeddings=True, lr_schedule="wsd",
        scan_layers=False, remat=False,
    )
