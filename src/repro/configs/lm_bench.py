"""Dense-LM benchmark net for the transformer-scale CHAOS studies
(DESIGN.md §10): a 2-layer GQA decoder deliberately attention-dominated
(seq 512 >> d_model 64) so the Pallas flash kernel's end-to-end training
win is visible in the worker-mesh cells, while the whole grid stays
CPU-benchmark sized.  GQA (2 kv heads under 4 query heads) matters for
more than realism: the jnp blockwise path pays a per-group gather the
kernel's grouped grid never materialises, so this is exactly the regime
the kernel forward earns its keep.  ``layer_chunk=1`` exposes one bucket
per layer — embed -> layers0 -> layers1 -> final_norm — the paper's
per-layer exchange granularity on the chunked layer stack."""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="lm-bench", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, tie_embeddings=True,
    scan_layers=True, remat=False,
    param_dtype="float32", layer_chunk=1,
)


def smoke_config() -> ArchConfig:
    return CONFIG  # already CPU-sized
