"""Paper Table 2 'Medium' CNN: C20@4x4 -> P2 -> C40@5x5 -> P3 -> FC150 -> 10."""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="chaos-medium", family="cnn",
    cnn_layers=(
        ("conv", 20, 4),   # 29 -> 26
        ("pool", 2),       # 26 -> 13
        ("conv", 40, 5),   # 13 -> 9
        ("pool", 3),       # 9 -> 3
        ("fc", 150),
    ),
    cnn_input=(29, 29), n_classes=10,
    param_dtype="float32", lr_schedule="decay",
    scan_layers=False, remat=False,
)


def smoke_config() -> ArchConfig:
    return CONFIG
