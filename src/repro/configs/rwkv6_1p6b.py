"""rwkv6-1.6b (Finch): attention-free, 24L d_model=2048 d_ff=7168 vocab=65536.

Data-dependent decay linear recurrence. [arXiv:2404.05892; unverified]
Sub-quadratic -> runs long_500k.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, d_head=64,
    d_ff=7168, vocab_size=65536,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, d_head=16,
        d_ff=128, vocab_size=256, scan_layers=False, remat=False,
    )
