"""Paper Table 2 'Small' CNN: 29x29 -> C5@4x4 -> P2 -> C10@5x5 -> P3 -> FC50 -> 10."""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="chaos-small", family="cnn",
    cnn_layers=(
        ("conv", 5, 4),    # 29 -> 26, 5 maps, 4x4 kernel
        ("pool", 2),       # 26 -> 13
        ("conv", 10, 5),   # 13 -> 9
        ("pool", 3),       # 9 -> 3
        ("fc", 50),
    ),
    cnn_input=(29, 29), n_classes=10,
    param_dtype="float32", lr_schedule="decay",
    scan_layers=False, remat=False,
)


def smoke_config() -> ArchConfig:
    return CONFIG  # already CPU-sized
