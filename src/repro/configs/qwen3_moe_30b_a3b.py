"""qwen3-moe-30b-a3b: MoE 48L d_model=2048 32H (GQA kv=4) vocab=151936.

128 experts, top-8, per-expert d_ff=768. [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, moe_d_ff=768,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=64, vocab_size=512, qk_norm=True,
        n_experts=8, top_k=2, moe_d_ff=64, capacity_factor=4.0,
        scan_layers=False, remat=False,
    )
