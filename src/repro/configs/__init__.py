"""Architecture config registry.

Each assigned architecture lives in its own module exposing ``CONFIG`` (the
full published config) and ``smoke_config()`` (a reduced same-family config
for CPU smoke tests).  ``get(name)`` / ``list_archs()`` are the public API.
"""
from __future__ import annotations

import importlib

from repro.core.types import ArchConfig, SHAPES, ShapeConfig  # noqa: F401

_ARCH_MODULES = [
    "qwen3_14b",
    "minicpm_2b",
    "minicpm3_4b",
    "mistral_nemo_12b",
    "llava_next_34b",
    "zamba2_1p2b",
    "rwkv6_1p6b",
    "qwen3_moe_235b_a22b",
    "qwen3_moe_30b_a3b",
    "whisper_small",
    # paper CNNs
    "chaos_small",
    "chaos_medium",
    "chaos_large",
    # transformer-scale CHAOS bench net (DESIGN.md §10)
    "lm_bench",
]

_ALIAS = {m.replace("_", "-"): m for m in _ARCH_MODULES}
_ALIAS.update({
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "zamba2-1.2b": "zamba2_1p2b",
    "rwkv6-1.6b": "rwkv6_1p6b",
})


def _module(name: str):
    key = name.replace("-", "_").replace(".", "p")
    if name in _ALIAS:
        key = _ALIAS[name]
    return importlib.import_module(f"repro.configs.{key}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ArchConfig:
    return _module(name).smoke_config()


def list_archs():
    return [m.replace("_", "-").replace("1p", "1.") for m in _ARCH_MODULES]


ASSIGNED = [
    "qwen3-14b",
    "minicpm-2b",
    "minicpm3-4b",
    "mistral-nemo-12b",
    "llava-next-34b",
    "zamba2-1.2b",
    "rwkv6-1.6b",
    "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b",
    "whisper-small",
]
