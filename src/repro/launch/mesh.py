"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips (TPU v5e pod); multi-pod adds a
leading pure-DP "pod" axis (2 x 256 = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int = None, axis: str = "workers"):
    """1-D mesh over available (possibly forced-host) devices, for the
    CHAOS worker-model runs and tests."""
    devs = jax.devices()
    n = n or len(devs)
    if n > len(devs):
        raise ValueError(
            f"requested a {n}-worker mesh but only {len(devs)} device(s) "
            f"are visible; set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n} in the environment BEFORE jax initialises to force "
            f"{n} host devices (tests/CI do this via subprocesses)")
    return jax.make_mesh((n,), (axis,), devices=devs[:n])
