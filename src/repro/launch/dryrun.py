import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).
(the two os.environ lines above MUST precede any jax import — jax locks the
device count on first init)

For every (architecture x input-shape) cell this lowers + compiles the real
train_step / serve_step under the production mesh with ShapeDtypeStruct
inputs (no allocation), prints memory/cost analysis, and records roofline
terms to a JSON results file.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as C
from repro.core import roofline as RL
from repro.core.chaos import SyncConfig
from repro.core.types import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_status, input_specs
from repro.models import layers as ML
from repro.models.api import get_ops
from repro.train import sharding as SH
from repro.train.step import (init_train_state, make_optimizer,
                              make_train_step, state_specs)


def _layers_pair(cfg):
    """(L1, L2) reduced layer counts for the roofline tier — one and two
    periods of the arch's repeating layer pattern."""
    period = cfg.attn_every if cfg.family == "hybrid" else 1
    L1 = max(2, period)
    return L1, 2 * L1


def _with_layers(cfg, n):
    kw = {"n_layers": n}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = max(1, round(cfg.n_enc_layers * n / cfg.n_layers))
    return dataclasses.replace(cfg, **kw)


def _batch_shardings(batch_abs, mesh):
    spec = jax.tree.map(lambda _: P("dp"), batch_abs)
    return SH.shardings_for(spec, batch_abs, mesh)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               sync_mode: str = "bsp", verbose: bool = True,
               compress: bool = False, extra_cfg: dict | None = None,
               unroll: bool = False, layers_override: int | None = None,
               rules: dict | None = None):
    """Lower+compile one cell.  Returns (compiled, info dict).

    unroll=False: production program (scan over layers) — compile-success
    proof + memory analysis.  unroll=True (+layers_override): straight-line
    HLO for roofline accounting (cost analysis counts loop bodies once).
    """
    cfg = C.get(arch)
    if layers_override:
        cfg = _with_layers(cfg, layers_override)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    if status != "ok":
        return None, {"arch": arch, "shape": shape_name, "status": status}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    sync = SyncConfig(mode=sync_mode, compress=compress)
    ops = get_ops(cfg)
    ML.UNROLL_ATTN = unroll
    t0 = time.time()
    try:
        with SH.use_mesh(mesh, rules):
            if shape.kind == "train":
                optimizer = make_optimizer(cfg)
                state_abs = init_train_state(cfg, jax.random.key(0), sync,
                                             optimizer, abstract=True)
                specs = state_specs(cfg, sync, optimizer)
                state_sh = SH.shardings_for(specs, state_abs, mesh,
                                            rules=rules)
                batch_abs = input_specs(cfg, shape)
                bsh = _batch_shardings(batch_abs, mesh)
                step = make_train_step(cfg, sync, optimizer)
                lowered = jax.jit(
                    step, in_shardings=(state_sh, bsh),
                    out_shardings=(state_sh, None),
                    donate_argnums=(0,),
                ).lower(state_abs, batch_abs)
            elif shape.kind == "prefill":
                pspecs = ops.param_specs()
                params_abs = ops.abstract_params()
                psh = SH.shardings_for(pspecs, params_abs, mesh, rules=rules)
                batch_abs = input_specs(cfg, shape)
                bsh = _batch_shardings(batch_abs, mesh)

                def prefill(params, batch):
                    # hidden states only; project just the LAST position
                    # (prefill never needs the full (B,T,V) logits)
                    if cfg.family == "encdec":
                        h, _ = ops.forward(params, batch["tokens"],
                                           batch["frames"],
                                           return_hidden=True)
                    elif cfg.family == "vlm":
                        h, _ = ops.forward(
                            params, batch["tokens"],
                            patch_embeds=batch["patch_embeds"],
                            return_hidden=True)
                    else:
                        h, _ = ops.forward(params, batch["tokens"],
                                           return_hidden=True)
                    out = params.get("out_embed", params["embed"])
                    logits = jnp.einsum("bd,vd->bv", h[:, -1], out)
                    return jnp.argmax(logits[:, :cfg.vocab_size], axis=-1)

                lowered = jax.jit(prefill, in_shardings=(psh, bsh)
                                  ).lower(params_abs, batch_abs)
            else:  # decode
                pspecs = ops.param_specs()
                params_abs = ops.abstract_params()
                psh = SH.shardings_for(pspecs, params_abs, mesh, rules=rules)
                cache_abs = ops.abstract_cache(shape.global_batch,
                                               shape.seq_len)
                csh = SH.shardings_for(
                    ops.cache_specs(shape.global_batch, shape.seq_len),
                    cache_abs, mesh, rules=rules)
                tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1),
                                               jnp.int32)
                tsh = SH.shardings_for(P("dp"), tok_abs, mesh)

                def serve(params, cache, tokens):
                    logits, new_cache = ops.decode(params, cache, tokens,
                                                   shape.seq_len - 1)
                    nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)
                    return nxt.astype(jnp.int32), new_cache

                lowered = jax.jit(
                    serve, in_shardings=(psh, csh, tsh),
                    out_shardings=(tsh, csh),
                    donate_argnums=(1,),
                ).lower(params_abs, cache_abs, tok_abs)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        ML.UNROLL_ATTN = False

    mf = RL.model_flops(cfg, shape)
    rl = RL.analyze(compiled, n_devices=n_dev, model_flops_total=mf)
    mem = compiled.memory_analysis()
    # XLA-CPU promotes bf16 buffers to f32 for compute (wrapped_convert
    # computations with identical shapes): those f32 copies do not exist on
    # the bf16-native TPU target.  Estimate the inflation so the report can
    # carry a TPU-corrected peak alongside the raw CPU-backend number.
    import re as _re
    cpu_promo = 0
    for mm in _re.finditer(
            r"\(param_[\d.]+: bf16\[([\d,]+)\]\) -> f32\[\1\]",
            compiled.as_text()):
        n = 1
        for dd in mm.group(1).split(","):
            if dd:
                n *= int(dd)
        cpu_promo += 4 * n
    info = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev), "sync": sync_mode,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 3),
            "cpu_bf16_promotion_gib": round(cpu_promo / 2**30, 3),
            "tpu_peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes
                 - cpu_promo) / 2**30, 3),
        } if mem else None,
        "roofline": rl.to_dict(),
    }
    if verbose:
        print(f"[{arch} x {shape_name} mesh={info['mesh']}] "
              f"compile={t_compile:.1f}s "
              f"peak/dev={info['memory_analysis']['peak_per_device_gib']}GiB "
              f"dominant={rl.dominant} "
              f"terms(c/m/x)={rl.compute_s:.4f}/{rl.memory_s:.4f}/"
              f"{rl.collective_s:.4f}s")
        print("  memory_analysis:", info["memory_analysis"])
        print("  cost_analysis: flops/dev=%.3e bytes/dev=%.3e" %
              (rl.flops, rl.bytes_accessed))
    return compiled, info


def roofline_cell(arch: str, shape_name: str, *, sync_mode: str = "bsp",
                  compress: bool = False, extra_cfg: dict | None = None,
                  verbose: bool = True, rules: dict | None = None):
    """Roofline tier: lower UNROLLED reduced-depth programs at two layer
    counts (L1, 2*L1) and extrapolate per-layer costs to the full depth.
    Exact for homogeneous stacks (all assigned archs repeat one pattern)."""
    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    if status != "ok":
        return {"arch": arch, "shape": shape_name, "status": status,
                "tier": "roofline"}
    L1, L2 = _layers_pair(cfg)
    L = cfg.n_layers
    kw = dict(sync_mode=sync_mode, compress=compress, extra_cfg=extra_cfg,
              verbose=False, unroll=True, rules=rules)
    _, i1 = lower_cell(arch, shape_name, layers_override=L1, **kw)
    _, i2 = lower_cell(arch, shape_name, layers_override=L2, **kw)
    r1, r2 = i1["roofline"], i2["roofline"]

    def ext(key):
        v1, v2 = r1[key], r2[key]
        return v2 + (L - L2) * (v2 - v1) / (L2 - L1)

    flops = ext("flops_per_dev")
    bytes_acc = ext("bytes_per_dev")
    coll_eff = ext("collective_effective_bytes")
    coll_tot = ext("collective_bytes_per_dev")
    mf = RL.model_flops(cfg, shape)
    n_dev = i2["n_devices"]
    compute_s = flops / RL.PEAK_FLOPS
    memory_s = bytes_acc / RL.HBM_BW
    coll_s = coll_eff / RL.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    info = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "tier": "roofline", "mesh": i2["mesh"], "n_devices": n_dev,
        "sync": sync_mode, "layers_pair": [L1, L2],
        "roofline": {
            "flops_per_dev": flops, "bytes_per_dev": bytes_acc,
            "collective_bytes_per_dev": coll_tot,
            "collective_effective_bytes": coll_eff,
            "collective_counts_L2": r2["collective_counts"],
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "bound_s": max(terms.values()),
            "roofline_fraction": max(terms.values()) / sum(terms.values()),
            "model_flops_total": mf,
            "model_flops_per_dev": mf / n_dev,
            "useful_flops_ratio": (mf / n_dev) / flops if flops else 0.0,
        },
    }
    if verbose:
        r = info["roofline"]
        print(f"[ROOFLINE {arch} x {shape_name}] dominant={dominant} "
              f"c/m/x = {compute_s:.4f}/{memory_s:.4f}/{coll_s:.4f} s "
              f"useful={r['useful_flops_ratio']:.2f}")
    return info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="also run the roofline tier (single-pod)")
    ap.add_argument("--sync", default="bsp")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in C.ASSIGNED:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))

    results = []
    out_path = args.out

    def record(info):
        results.append(info)
        if out_path:
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1, default=str)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch, shape in cells:
        for mp in meshes:
            try:
                _, info = lower_cell(arch, shape, multi_pod=mp,
                                     sync_mode=args.sync)
                info["tier"] = "production"
            except Exception as e:
                info = {"arch": arch, "shape": shape, "tier": "production",
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:]}
                print(f"[{arch} x {shape}] FAILED: {e}")
            record(info)
        if args.roofline:
            try:
                record(roofline_cell(arch, shape, sync_mode=args.sync))
            except Exception as e:
                print(f"[ROOFLINE {arch} x {shape}] FAILED: {e}")
                record({"arch": arch, "shape": shape, "tier": "roofline",
                        "status": f"FAIL: {type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:]})
    ok = sum(1 for r in results if r.get("status") == "ok")
    skip = sum(1 for r in results if str(r.get("status", "")).startswith("skip"))
    print(f"\n== dry-run: {ok} ok, {skip} skipped, "
          f"{len(results) - ok - skip} failed, {len(results)} total ==")


if __name__ == "__main__":
    main()
