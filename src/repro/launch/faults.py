"""Deterministic, seedable fault injection for the training driver
(DESIGN.md §7) — the test surface for elastic membership changes and
checkpoint hardening.  A production pod loses workers, tears checkpoint
writes, and hits transient filesystem blips; this module makes each of
those a one-line, reproducible event instead of an un-testable accident.

Spec grammar (driver ``--inject``, comma-separated events)::

    kill@6:to=3        worker-kill: at the first superstep boundary >= step
                       6, the membership drops to 3 workers (default
                       to = N-1); the driver resizes in place (DESIGN.md
                       §7 ladder)
    torn@8             torn checkpoint write: the checkpoint that lands at
    torn@8:frac=0.5    step 8 is truncated at byte k = frac * size (frac
    torn@8:byte=100    drawn from the injection seed when unspecified) —
                       restore must detect it via the manifest CRC/length
                       stamp and fall back to the previous step
    io@restore:times=2 transient restore IO: the first 2 payload-read
                       attempts raise OSError (the manager's bounded
                       backoff must absorb them)
    stall@6:ms=250     straggler stall: the superstep ending at the first
                       boundary >= step 6 sleeps 250 ms on the host (trips
                       the watchdog; with --evict-stragglers, feeds the
                       resize controller)
    resizefail@6       poison the NEXT in-memory resize attempted at a
                       boundary >= step 6 (each retry re-raises), forcing
                       the degradation ladder onto its checkpoint-restore
                       rung

Every event fires ONCE (one-shot) and is appended to ``FaultPlan.log`` so
tests and the driver's ``--metrics-out`` artifact can assert exactly what
fired where.  All randomness (the unspecified torn fraction) comes from
the plan's seed — two plans with the same spec + seed inject bit-identical
faults.
"""
from __future__ import annotations

import dataclasses
import os
import random
import time
from typing import List, Optional


@dataclasses.dataclass
class _Event:
    kind: str            # kill | torn | io | stall | resizefail
    step: object         # int boundary threshold, or "restore" for io
    params: dict
    fired: bool = False


def _parse_params(parts: List[str]) -> dict:
    out = {}
    for p in parts:
        if not p:
            continue
        k, _, v = p.partition("=")
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


class FaultPlan:
    """Parsed ``--inject`` spec.  Hooks are called by the driver (membership
    / stall / resize poison) and by ``CheckpointManager`` (torn write /
    restore IO); unknown-at-parse-time values (``to`` for a kill, the torn
    fraction) resolve lazily from the run context or the seed."""

    KINDS = ("kill", "torn", "io", "stall", "resizefail")

    def __init__(self, events: List[_Event], seed: int = 0):
        self.events = events
        self.rng = random.Random(seed)
        self.seed = seed
        self.log: List[dict] = []
        self._io_budget = sum(e.params.get("times", 1) for e in events
                              if e.kind == "io")

    @classmethod
    def from_spec(cls, spec: Optional[str], seed: int = 0
                  ) -> Optional["FaultPlan"]:
        if not spec:
            return None
        events = []
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            head, _, rest = item.partition(":")
            kind, _, at = head.partition("@")
            if kind not in cls.KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r} in --inject {spec!r}; "
                    f"known kinds: {', '.join(cls.KINDS)}")
            if not at:
                raise ValueError(
                    f"fault {item!r} needs an @<step> anchor (or @restore "
                    f"for io)")
            step = at if kind == "io" else int(at)
            events.append(_Event(kind, step, _parse_params(rest.split(":"))))
        return cls(events, seed)

    def _record(self, event: _Event, **extra):
        event.fired = True
        entry = {"kind": event.kind, "at": event.step, **event.params,
                 **extra}
        self.log.append(entry)
        print(f"[faults] injected {entry}", flush=True)

    # -- driver hooks -------------------------------------------------------
    def membership_event(self, boundary_step: int,
                         current_workers: int) -> Optional[int]:
        """Target worker count if a kill fires at this superstep boundary
        (one kill per call: sequential kills need separate boundaries)."""
        for e in self.events:
            if e.kind == "kill" and not e.fired and boundary_step >= e.step:
                target = int(e.params.get("to", current_workers - 1))
                self._record(e, boundary=boundary_step, target=target)
                return target
        return None

    def stall(self, boundary_step: int) -> float:
        """Sleep (on the host, inside the timed superstep window) if a
        stall fires at this boundary; returns the injected seconds."""
        for e in self.events:
            if e.kind == "stall" and not e.fired and boundary_step >= e.step:
                ms = float(e.params.get("ms", 200))
                self._record(e, boundary=boundary_step, ms=ms)
                time.sleep(ms / 1e3)
                return ms / 1e3
        return 0.0

    def resize_poison(self, boundary_step: int) -> bool:
        """True if the next in-memory resize at this boundary must fail
        (consumed once — the ladder's checkpoint-restore rung is next)."""
        for e in self.events:
            if (e.kind == "resizefail" and not e.fired
                    and boundary_step >= e.step):
                self._record(e, boundary=boundary_step)
                return True
        return False

    # -- CheckpointManager hooks --------------------------------------------
    def on_checkpoint_written(self, step: int, final_dir: str):
        """Tear the payload of the checkpoint that landed at ``step`` —
        simulating a power loss the atomic rename cannot save us from
        (data blocks never made it to the platter)."""
        for e in self.events:
            if e.kind == "torn" and not e.fired and step >= e.step:
                payload = os.path.join(final_dir, "arrays.npz")
                size = os.path.getsize(payload)
                if "byte" in e.params:
                    k = min(int(e.params["byte"]), size)
                else:
                    frac = e.params.get("frac", self.rng.uniform(0.1, 0.9))
                    k = int(size * float(frac))
                with open(payload, "rb+") as f:
                    f.truncate(k)
                self._record(e, ckpt_step=step, torn_at_byte=k,
                             payload_bytes=size)

    def on_restore_read(self, path: str, attempt: int):
        """Raise a transient OSError for the first ``times`` read attempts
        of any restore (the manager's backoff retries through them)."""
        for e in self.events:
            if e.kind == "io" and not e.fired:
                times = int(e.params.get("times", 1))
                budget = e.params.setdefault("_spent", 0)
                if budget < times:
                    e.params["_spent"] = budget + 1
                    self.log.append({"kind": "io", "attempt": attempt,
                                     "path": os.path.basename(path)})
                    print(f"[faults] injected transient restore IO error "
                          f"(attempt {attempt})", flush=True)
                    raise OSError(
                        f"injected transient IO error "
                        f"({e.params['_spent']}/{times})")
                e.fired = True
        return None
