"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation — the dry-run lowers against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig, SHAPES, ShapeConfig

SKIP = "skip"


def cell_status(cfg: ArchConfig, shape: ShapeConfig) -> str:
    """'ok' or a skip reason, per the assignment rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return "skip: full attention is quadratic at 512k (DESIGN.md §5)"
    return "ok"


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Returns a dict of ShapeDtypeStructs for train_step / serve_step."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
            "labels": jax.ShapeDtypeStruct((B, T), i32),
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a cache of length T
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
