"""Continuous-batching serving driver (DESIGN.md §9).

Wraps ``repro.serve.ServeEngine``: a slot-based paged KV cache, batched
prefill (whole prompts in one dispatch through the q_offset-aware flash
attention), and an admit/evict scheduler that steps every occupied slot in
one compiled dispatch per token with on-device greedy sampling.

    # static batch (the old serve() shape — all requests arrive at t=0):
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --batch 4 --prompt-len 32 --gen 32

    # continuous batching under a seeded Poisson trace:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --slots 4 --requests 16 --rate 0.5 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import repro.configs as C
from repro.obs import MetricsBus, Tracer
from repro.serve.engine import (Request, RequestFeed, ServeEngine,
                                poisson_trace)


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 32,
          max_seq: int = 128, smoke: bool = True, seed: int = 0,
          prefill_mode: str = "batched", use_kernel: bool = False,
          temperature: float = 0.0, top_p: float = 1.0):
    """Static-batch serving (compat shape): ``batch`` equal-length prompts
    all arrive at t=0, each generates ``gen`` tokens.  Returns the
    (batch, gen) generated tokens.  Dispatch contract: 1 batched prefill +
    (gen - 1) decode dispatches — no trailing wasted decode."""
    cfg = C.smoke(arch) if smoke else C.get(arch)
    eng = ServeEngine(arch, slots=batch, max_seq=max_seq, smoke=smoke,
                      seed=seed, prefill_mode=prefill_mode,
                      use_kernel=use_kernel, temperature=temperature,
                      top_p=top_p)
    rng = np.random.default_rng(seed)
    trace = [Request(rid=i,
                     tokens=rng.integers(0, cfg.vocab_size,
                                         size=(prompt_len,)).astype(np.int32),
                     max_new=gen, arrival=0.0)
             for i in range(batch)]
    t0 = time.time()
    finished = eng.run(trace)
    dt = time.time() - t0
    gen_tokens = np.stack([f.tokens for f in finished])
    tput = (eng.counters["prefill_tokens"]
            + eng.counters["decode_tokens"]) / dt
    print(f"[serve {arch}] generated {gen_tokens.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s incl. prefill; dispatches: "
          f"{eng.counters['prefill_dispatch']} prefill + "
          f"{eng.counters['decode_dispatch']} decode)")
    return gen_tokens


def serve_trace(arch: str, *, slots: int = 4, requests: int = 16,
                rate: float = 0.5, prompt_lens=(8, 32), gen: int = 16,
                max_seq: int = 128, smoke: bool = True, seed: int = 0,
                prefill_mode: str = "batched", use_kernel: bool = False,
                feed_depth: int = 64, temperature: float = 0.0,
                top_p: float = 1.0, tracer=None, bus=None):
    """Continuous batching under a seeded Poisson trace.  The RequestFeed
    thread replays the trace into a bounded queue (the PrefetchFeed
    feed/compute split) while the engine loop admits, decodes, and evicts.
    Returns (finished, counters, step_times_s)."""
    cfg = C.smoke(arch) if smoke else C.get(arch)
    eng = ServeEngine(arch, slots=slots, max_seq=max_seq, smoke=smoke,
                      seed=seed, prefill_mode=prefill_mode,
                      use_kernel=use_kernel, temperature=temperature,
                      top_p=top_p, tracer=tracer, bus=bus)
    trace = poisson_trace(seed, requests, rate, cfg.vocab_size,
                          prompt_lens=prompt_lens, max_new=gen)
    feed = RequestFeed(trace, depth=feed_depth)
    feed.start()
    finished, step_times = [], []
    n_seen = 0
    while n_seen < requests or eng.pending or eng.active:
        for req in feed.drain():
            eng.submit(req)
            n_seen += 1
        if not (eng.pending or eng.active):
            time.sleep(0.001)                # feed not caught up yet
            continue
        t0 = time.time()
        finished.extend(eng.step())
        step_times.append(time.time() - t0)
    feed.stop()
    return sorted(finished, key=lambda f: f.rid), eng.counters, step_times


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=0,
                    help="run continuous batching with this many cache "
                         "slots under a Poisson trace (0 = static batch)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per virtual s)")
    ap.add_argument("--prefill-mode", default="batched",
                    choices=("batched", "loop"))
    ap.add_argument("--use-kernel", action="store_true",
                    help="route GQA prefill through the Pallas flash kernel")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="> 0 enables seeded sampling fused into the decode "
                         "dispatch (0 = greedy, the default)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (only with --temperature)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto trace.json of the engine "
                         "lifecycle here (DESIGN.md §11)")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    tracer = Tracer("serve") if args.trace_out else None
    bus = MetricsBus() if args.trace_out else None
    if args.slots:
        finished, counters, times = serve_trace(
            args.arch, slots=args.slots, requests=args.requests,
            rate=args.rate, gen=args.gen,
            prompt_lens=(max(4, args.prompt_len // 2), args.prompt_len),
            max_seq=args.prompt_len + args.gen + 8,
            smoke=not args.full_config, seed=args.seed,
            prefill_mode=args.prefill_mode, use_kernel=args.use_kernel,
            temperature=args.temperature, top_p=args.top_p,
            tracer=tracer, bus=bus)
        toks = sum(f.prompt_len + len(f.tokens) for f in finished)
        dt = sum(times)
        print(f"[serve-trace {args.arch}] {len(finished)} requests, "
              f"{toks} tokens in {dt:.2f}s ({toks / dt:.1f} tok/s); "
              f"dispatches: {counters['prefill_dispatch']} prefill + "
              f"{counters['decode_dispatch']} decode")
    else:
        serve(args.arch, args.batch, args.prompt_len, args.gen,
              max_seq=args.prompt_len + args.gen + 8,
              smoke=not args.full_config, seed=args.seed,
              prefill_mode=args.prefill_mode, use_kernel=args.use_kernel,
              temperature=args.temperature, top_p=args.top_p)
    if tracer is not None:
        tracer.write(args.trace_out)
        s = bus.summary()
        if s["histograms"]:
            print("[obs] serve histograms:",
                  {k: round(v["mean"], 4)
                   for k, v in s["histograms"].items()})


if __name__ == "__main__":
    main()
