"""Batched serving driver: prefill a batch of prompts, then decode with the
cached-state serve_step.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models.api import get_ops


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 32,
          max_seq: int = 128, smoke: bool = True, seed: int = 0):
    cfg = C.smoke(arch) if smoke else C.get(arch)
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(seed))
    cache = ops.init_cache(batch, max_seq)

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(batch, prompt_len)).astype(np.int32)

    decode = jax.jit(ops.decode, donate_argnums=(1,),
                     static_argnames=())

    # prefill token-by-token through the decode path (correctness-first
    # reference; the dry-run prefill program is the batched fast path)
    toks = jnp.asarray(prompts)
    t0 = time.time()
    for i in range(prompt_len):
        logits, cache = decode(params, cache, toks[:, i:i + 1], i)
    out = []
    cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    for i in range(gen):
        out.append(np.asarray(cur))
        logits, cache = decode(params, cache, cur, prompt_len + i)
        cur = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen_tokens = np.concatenate(out, axis=1)
    tput = batch * (prompt_len + gen) / dt
    print(f"[serve {arch}] generated {gen_tokens.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s incl. prefill)")
    return gen_tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.gen,
          max_seq=args.prompt_len + args.gen + 8,
          smoke=not args.full_config)


if __name__ == "__main__":
    main()
