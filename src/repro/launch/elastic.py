"""Elastic membership: resize the worker mesh mid-run, or bring a training
job back on a different topology.

Two layers of fault tolerance live here (DESIGN.md §7):

**Process restart** (``resume_elastic``): checkpoints are device-agnostic
(checkpoint/manager.py); given the latest checkpoint and whatever devices
the scheduler gives us NOW, rebuild the mesh, the shardings, and the
compiled step — e.g. a 2-pod job resuming on 1 pod after a pod loss, or
scaling 8 -> 16 hosts.

    state, mesh, step_fn = resume_elastic(cfg, sync, ckpt_dir,
                                          mesh_shape=(8,), axes=("data",))

**In-process resize** (``ResizeController``): the driver's worker-mesh
route grows/shrinks N -> N' at a superstep boundary WITHOUT restarting the
process — the in-memory TrainState is re-slotted through the strategy's
``resize_state`` hook (replicated bsp/chaos state passes through bit-exact;
worker-stacked state follows ``reslot_stacked``'s shrink/grow rule), the
mesh + compiled superstep are rebuilt, and training continues.  The
degradation ladder when that fails:

    1. in-memory resize (retried with bounded backoff)
    2. checkpoint-restore at N' (worker-count-invariant checkpoints make
       this exact for bsp / chaos τ=0)
    3. continue at the old N with an actionable log — never a crash

The per-step global batch is unchanged in all cases (the data pipeline is
keyed by step count, not by device count), so bsp/chaos-replicated loss
curves continue exactly; only the per-device slice sizes change.
"""
from __future__ import annotations

import math
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.chaos import SyncConfig
from repro.core.types import ArchConfig, WorkerConfig
from repro.train import sharding as SH
from repro.train.step import (init_train_state, init_worker_state,
                              make_optimizer, make_train_step,
                              make_worker_superstep, resize_worker_state,
                              state_specs)
from repro.train.sync import get_strategy


def make_mesh_from_available(mesh_shape: Optional[Sequence[int]] = None,
                             axes: Sequence[str] = ("data", "model")):
    """Build a mesh from the devices that exist right now.  Default: 1-D
    data mesh over every live device (the maximally elastic layout).  An
    explicit ``mesh_shape`` that over-asks the visible device count is a
    hard error naming both numbers and the remedy (mirrors
    ``launch/mesh.py::make_host_mesh``), never a silent truncation."""
    devs = jax.devices()
    if mesh_shape is None:
        mesh_shape = (len(devs),)
        axes = axes[:1]
    need = math.prod(mesh_shape)
    if need > len(devs):
        raise ValueError(
            f"mesh_shape {tuple(mesh_shape)} needs {need} device(s) but "
            f"only {len(devs)} are visible; shrink the mesh or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} in "
            f"the environment BEFORE jax initialises to force host devices")
    return jax.make_mesh(tuple(mesh_shape), tuple(axes),
                         devices=devs[:need])


def resume_elastic(cfg: ArchConfig, sync: SyncConfig, ckpt_dir: str,
                   mesh_shape: Optional[Sequence[int]] = None,
                   axes: Sequence[str] = ("data", "model"),
                   optimizer=None):
    """Restore the latest checkpoint under a freshly built mesh.

    Returns (state, start_step, mesh, jit_step).  The restored arrays are
    device_put with shardings derived for the NEW mesh — axes that no
    longer divide (e.g. model=16 shrank to model=4) fall back per-dim via
    shardings_for's divisibility rule.
    """
    optimizer = optimizer or make_optimizer(cfg)
    mesh = make_mesh_from_available(mesh_shape, axes)
    mgr = CheckpointManager(ckpt_dir)

    with SH.use_mesh(mesh):
        template = init_train_state(cfg, jax.random.key(0), sync, optimizer,
                                    abstract=True)
        specs = state_specs(cfg, sync, optimizer)
        shardings = SH.shardings_for(specs, template, mesh)
        state, start = mgr.restore(template, shardings=shardings)
        step_fn = jax.jit(make_train_step(cfg, sync, optimizer),
                          in_shardings=(shardings, None),
                          out_shardings=(shardings, None),
                          donate_argnums=(0,))
    return state, start, mesh, step_fn


class ResizeOutcome:
    """What one membership change actually did (driver log + BENCH rows)."""

    def __init__(self, requested: int, path: str, old_n: int, new_n: int,
                 latency_s: float, detail: str = "",
                 restart_step: Optional[int] = None):
        self.requested = requested
        self.path = path  # "in-memory" | "ckpt-restore" | "degraded" | "no-op"
        self.old_n = old_n
        self.new_n = new_n
        self.latency_s = latency_s
        self.detail = detail
        #: set on the ckpt-restore rung: the step training must replay from
        #: (the restored checkpoint may be older than the boundary)
        self.restart_step = restart_step

    def as_dict(self) -> dict:
        return {"requested": self.requested, "path": self.path,
                "from": self.old_n, "to": self.new_n,
                "latency_s": self.latency_s, "detail": self.detail,
                "restart_step": self.restart_step}


class ResizeController:
    """Driver-side elastic membership protocol (DESIGN.md §7).

    Owns the worker-route build state (WorkerConfig, mesh, compiled
    superstep) and re-slots it across membership-change events — a signal,
    a watchdog straggler verdict, or an injected fault — at superstep
    boundaries.  The driver drains the in-flight superstep (it only calls
    ``resize`` between supersteps), then:

    1. **in-memory resize** (the path, not the fallback): re-slot the live
       TrainState via ``train/step.py::resize_worker_state`` (replicated
       state passes through bit-exact; stacked state follows the
       documented shrink/grow rule), rebuild mesh + compiled superstep at
       N', continue.  Retried ``retries`` times with bounded backoff.
    2. **checkpoint-restore at N'**: rebuild from the newest valid
       checkpoint under the new worker count (exact for worker-count-
       invariant layouts; a stacked checkpoint pinned to the old N fails
       its shape check and falls through).
    3. **continue degraded at the old N** with an actionable log — a
       failed resize must never kill a healthy run.

    **Straggler re-admission** (``readmit_after``): a worker evicted on a
    straggler verdict is usually a *transient* straggler (GC pause, noisy
    neighbour, page-cache cold start) — permanently running degraded wastes
    the machine.  When ``readmit_after`` is set, a straggler-reason shrink
    arms a probation window: after that many consecutive clean supersteps
    (no straggler verdict) the controller requests a grow back to the
    pre-eviction worker count; any straggle during probation resets the
    window.  Both transitions are logged.
    """

    def __init__(self, cfg: ArchConfig, sync: SyncConfig, optimizer,
                 worker: WorkerConfig, mesh, ckpt_mgr=None,
                 retries: int = 2, backoff_s: float = 0.05, fault=None,
                 readmit_after: Optional[int] = None):
        self.cfg = cfg
        self.sync = sync
        self.optimizer = optimizer
        self.worker = worker
        self.mesh = mesh
        self.ckpt_mgr = ckpt_mgr
        self.retries = retries
        self.backoff_s = backoff_s
        self.fault = fault
        self.readmit_after = readmit_after
        #: (pre-eviction worker count, clean supersteps still required)
        self._probation: Optional[tuple] = None
        self._pending: Optional[tuple] = None
        self.outcomes: list = []

    # -- event intake -------------------------------------------------------
    def request(self, target_workers: int, reason: str):
        """Record a membership-change request; the driver applies it at the
        next superstep boundary (latest request wins)."""
        self._pending = (target_workers, reason)
        print(f"[elastic] membership change requested: {reason} -> "
              f"target {target_workers} worker(s)", flush=True)

    def take_pending(self) -> Optional[tuple]:
        p, self._pending = self._pending, None
        return p

    def observe_boundary(self, straggled: bool):
        """Feed every superstep boundary's watchdog verdict to the
        probation clock: a straggle resets the window, ``readmit_after``
        consecutive clean boundaries trigger the re-admit request."""
        if self._probation is None:
            return
        old_n, remaining = self._probation
        if straggled:
            self._probation = (old_n, self.readmit_after)
            print(f"[elastic] probation reset: straggled again; "
                  f"{self.readmit_after} clean supersteps required before "
                  f"re-admission to N={old_n}", flush=True)
            return
        remaining -= 1
        if remaining > 0:
            self._probation = (old_n, remaining)
            return
        self._probation = None
        print(f"[elastic] probation served: {self.readmit_after} clean "
              f"superstep(s); re-admitting evicted worker(s) -> N={old_n}",
              flush=True)
        self.request(old_n, "straggler probation served")

    # -- the resize protocol ------------------------------------------------
    def _build(self, worker: WorkerConfig):
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(worker.workers)
        super_fn = make_worker_superstep(self.cfg, self.sync, worker, mesh,
                                         self.optimizer)
        return mesh, super_fn

    def _clamp(self, requested: int) -> int:
        n = self.worker.clamp_workers(max(requested, 1))
        if n != requested:
            print(f"[elastic] target {requested} does not divide "
                  f"logical_shards={self.worker.logical_shards}; landing "
                  f"on N'={n}", flush=True)
        return n

    def _maybe_arm_probation(self, old_n: int, new_n: int, reason: str):
        """A successful straggler-verdict shrink starts (or extends) the
        re-admission probation window; a successful grow back to (or past)
        the probation target clears it."""
        if self.readmit_after is None:
            return
        if new_n < old_n and "straggler" in reason:
            prev = self._probation[0] if self._probation else 0
            self._probation = (max(old_n, prev), self.readmit_after)
            print(f"[elastic] probation armed: evicted straggler(s) "
                  f"re-admitted back to N={self._probation[0]} after "
                  f"{self.readmit_after} clean superstep(s)", flush=True)
        elif self._probation is not None and new_n >= self._probation[0]:
            self._probation = None

    def resize(self, state, requested: int, boundary_step: int,
               reason: str = ""):
        """Apply a membership change at a superstep boundary.  Returns
        ``(state, super_fn, outcome)`` and updates ``self.worker`` /
        ``self.mesh`` — on the degraded rung they keep their old values and
        the returned state is the (host-snapshotted, re-placed) input."""
        old = self.worker
        target = self._clamp(requested)
        t0 = time.perf_counter()
        if target == old.workers:
            out = ResizeOutcome(requested, "no-op", old.workers,
                                old.workers, time.perf_counter() - t0,
                                "target equals current membership")
            self.outcomes.append(out)
            return state, None, out

        new_worker = old.resized(target)
        # snapshot the live state to host numpy ONCE: the arrays come back
        # UNCOMMITTED, so the rebuilt superstep is free to place them under
        # the new mesh (a device-committed tree would poison the next jit
        # call with the old mesh's device set); the degraded rung re-places
        # the same snapshot under the old mesh
        host_state = jax.tree.map(
            lambda x: np.asarray(x) if hasattr(x, "shape") else x, state)
        poisoned = (self.fault is not None
                    and self.fault.resize_poison(boundary_step))

        # rung 1: in-memory resize, retried with bounded backoff
        last_err = None
        for attempt in range(self.retries + 1):
            try:
                if poisoned:
                    raise RuntimeError(
                        "injected resize failure (--inject resizefail)")
                new_state = resize_worker_state(host_state, self.sync, old,
                                                new_worker)
                mesh, super_fn = self._build(new_worker)
                self.worker, self.mesh = new_worker, mesh
                out = ResizeOutcome(
                    requested, "in-memory", old.workers, target,
                    time.perf_counter() - t0,
                    get_strategy(self.sync).checkpoint_layout())
                self.outcomes.append(out)
                print(f"[elastic] resized {old.workers} -> {target} "
                      f"worker(s) in-memory at step {boundary_step} "
                      f"({out.latency_s * 1e3:.0f}ms)", flush=True)
                self._maybe_arm_probation(old.workers, target, reason)
                return new_state, super_fn, out
            except Exception as e:
                last_err = e
                if attempt < self.retries:
                    delay = self.backoff_s * (2 ** attempt)
                    print(f"[elastic] in-memory resize attempt "
                          f"{attempt + 1}/{self.retries + 1} failed: {e}; "
                          f"retrying in {delay:.2f}s", flush=True)
                    time.sleep(delay)
        print(f"[elastic] in-memory resize {old.workers} -> {target} "
              f"failed after {self.retries + 1} attempt(s): {last_err}; "
              f"falling back to checkpoint-restore at N'={target}",
              flush=True)

        # rung 2: checkpoint-restore at N'
        if self.ckpt_mgr is not None:
            try:
                mesh, super_fn = self._build(new_worker)
                template = init_worker_state(self.cfg, jax.random.key(0),
                                             self.sync, new_worker,
                                             self.optimizer)
                new_state, ckpt_step = self.ckpt_mgr.restore(template)
                self.worker, self.mesh = new_worker, mesh
                out = ResizeOutcome(
                    requested, "ckpt-restore", old.workers, target,
                    time.perf_counter() - t0,
                    f"restored checkpoint step {ckpt_step} "
                    f"(boundary was {boundary_step})",
                    restart_step=ckpt_step)
                self.outcomes.append(out)
                print(f"[elastic] resized {old.workers} -> {target} via "
                      f"checkpoint step {ckpt_step} "
                      f"({out.latency_s * 1e3:.0f}ms)", flush=True)
                self._maybe_arm_probation(old.workers, target, reason)
                return new_state, super_fn, out
            except Exception as e:
                print(f"[elastic] checkpoint-restore at N'={target} "
                      f"failed: {e}", flush=True)
        else:
            print("[elastic] no checkpoint manager configured (--ckpt-dir) "
                  "— cannot take the restore rung", flush=True)

        # rung 3: continue degraded at the old N — never a crash
        out = ResizeOutcome(
            requested, "degraded", old.workers, old.workers,
            time.perf_counter() - t0,
            f"resize to {target} failed on every rung; continuing at "
            f"N={old.workers} — if a worker is genuinely gone, expect the "
            f"next superstep to fail; checkpoint and restart with "
            f"--workers {target}")
        self.outcomes.append(out)
        print(f"[elastic] DEGRADED: {out.detail}", flush=True)
        return host_state, None, out
