"""Elastic re-meshing: bring a training job back on a different topology.

Checkpoints are device-agnostic (checkpoint/manager.py); this module owns
the other half of fault tolerance at pod scale: given the latest checkpoint
and whatever devices the scheduler gives us NOW, rebuild the mesh, the
shardings, and the compiled step — e.g. a 2-pod job resuming on 1 pod after
a pod loss, or scaling 8 -> 16 hosts.

    state, mesh, step_fn = resume_elastic(cfg, sync, ckpt_dir,
                                          mesh_shape=(8,), axes=("data",))

The per-step global batch is unchanged (the data pipeline is keyed by step
count, not by device count), so loss curves continue exactly; only the
per-device slice sizes change.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core.chaos import SyncConfig
from repro.core.types import ArchConfig
from repro.train import sharding as SH
from repro.train.step import (init_train_state, make_optimizer,
                              make_train_step, state_specs)


def make_mesh_from_available(mesh_shape: Optional[Sequence[int]] = None,
                             axes: Sequence[str] = ("data", "model")):
    """Build a mesh from the devices that exist right now.  Default: 1-D
    data mesh over every live device (the maximally elastic layout)."""
    devs = jax.devices()
    if mesh_shape is None:
        mesh_shape = (len(devs),)
        axes = axes[:1]
    return jax.make_mesh(tuple(mesh_shape), tuple(axes),
                         devices=devs[:int(__import__("math").prod(mesh_shape))])


def resume_elastic(cfg: ArchConfig, sync: SyncConfig, ckpt_dir: str,
                   mesh_shape: Optional[Sequence[int]] = None,
                   axes: Sequence[str] = ("data", "model"),
                   optimizer=None):
    """Restore the latest checkpoint under a freshly built mesh.

    Returns (state, start_step, mesh, jit_step).  The restored arrays are
    device_put with shardings derived for the NEW mesh — axes that no
    longer divide (e.g. model=16 shrank to model=4) fall back per-dim via
    shardings_for's divisibility rule.
    """
    optimizer = optimizer or make_optimizer(cfg)
    mesh = make_mesh_from_available(mesh_shape, axes)
    mgr = CheckpointManager(ckpt_dir)

    with SH.use_mesh(mesh):
        template = init_train_state(cfg, jax.random.key(0), sync, optimizer,
                                    abstract=True)
        specs = state_specs(cfg, sync, optimizer)
        shardings = SH.shardings_for(specs, template, mesh)
        state, start = mgr.restore(template, shardings=shardings)
        step_fn = jax.jit(make_train_step(cfg, sync, optimizer),
                          in_shardings=(shardings, None),
                          out_shardings=(shardings, None),
                          donate_argnums=(0,))
    return state, start, mesh, step_fn
