"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b-smoke \
        --steps 200 --sync chaos --ckpt-dir /tmp/ckpt [--batch 8 --seq 256]

Features (framework-scale runtime, DESIGN.md §3):
  - checkpoint/restart: atomic keep-N checkpoints, auto-resume from latest,
    deterministic data pipeline keyed by step (resume == replay);
  - CHAOS sync modes (bsp | chaos | localsgd) for the gradient exchange;
  - straggler watchdog: per-step wall-time z-score detection with logging
    (SPMD cannot work-steal; slow steps are surfaced for the scheduler);
  - elastic re-meshing: on restore, arrays are placed under the *current*
    mesh's shardings, so a job can come back on fewer/more chips;
  - preemption simulation via --die-at-step (used by the fault-tolerance
    integration test).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.core.chaos import SyncConfig
from repro.data.pipeline import TokenPipeline
from repro.train import sharding as SH
from repro.train.step import init_train_state, make_optimizer, make_train_step


class StragglerWatchdog:
    """Flags steps slower than mean + z*std over a sliding window."""

    def __init__(self, window: int = 50, z: float = 3.0):
        self.times = []
        self.window = window
        self.z = z
        self.flagged = []

    def observe(self, step: int, dt: float):
        if len(self.times) >= 10:
            mu = statistics.fmean(self.times)
            sd = statistics.pstdev(self.times) or 1e-9
            if dt > mu + self.z * sd:
                self.flagged.append((step, dt, mu))
                print(f"[watchdog] step {step} straggled: {dt * 1e3:.1f}ms "
                      f"vs mean {mu * 1e3:.1f}ms", flush=True)
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)


def train(arch: str, steps: int, sync_mode: str = "bsp", batch: int = 8,
          seq: int = 256, ckpt_dir: str | None = None,
          ckpt_every: int = 50, die_at_step: int | None = None,
          base_lr: float = 3e-4, compress: bool = False,
          log_every: int = 10, smoke: bool = True):
    cfg = C.smoke(arch) if smoke else C.get(arch)
    sync = SyncConfig(mode=sync_mode, compress=compress)
    optimizer = make_optimizer(cfg, base_lr=base_lr, total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, sync, optimizer),
                      donate_argnums=(0,))
    pipe = TokenPipeline(cfg.vocab_size, batch, seq)

    state = init_train_state(cfg, jax.random.key(0), sync, optimizer)
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep_n=3)
        if mgr.latest_step() is not None:
            state, start = mgr.restore(state)
            print(f"[train] resumed from step {start}", flush=True)

    watchdog = StragglerWatchdog()
    losses = []
    for step in range(start, steps):
        t0 = time.time()
        batch_np = pipe.batch_at(step)
        state, metrics = step_fn(state, batch_np)
        loss = float(metrics["loss"])
        losses.append(loss)
        watchdog.observe(step, time.time() - t0)
        if step % log_every == 0:
            print(f"[train {arch} sync={sync_mode}] step {step} "
                  f"loss={loss:.4f}", flush=True)
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state, blocking=False)
        if die_at_step is not None and step + 1 == die_at_step:
            if mgr:
                mgr.wait()
            print(f"[train] simulated preemption at step {step + 1}",
                  flush=True)
            sys.exit(17)
    if mgr:
        mgr.save(steps, state, blocking=True)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--sync", default="bsp",
                    choices=["bsp", "chaos", "localsgd"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--die-at-step", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.sync, args.batch, args.seq,
                      args.ckpt_dir, args.ckpt_every, args.die_at_step,
                      args.lr, args.compress, smoke=not args.full_config)
    print(f"[train] done: first-10 mean {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
