"""Fault-tolerant superstep training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b-smoke \
        --steps 200 --sync chaos --superstep 8 --ckpt-dir /tmp/ckpt \
        [--batch 8 --seq 256]

Features (framework-scale runtime, DESIGN.md §3):
  - SUPERSTEP execution: K steps run inside one compiled ``lax.scan``
    dispatch with full TrainState donation; the host syncs on metrics once
    per K steps (loss comes back as a (K,)-vector) instead of once per
    step — the per-step dispatch + host-roundtrip overhead amortizes 1/K;
  - on-device prefetch: a double-buffered background feed builds the NEXT
    superstep's stacked (K, B, ...) batch and ships it to the device while
    the current superstep computes;
  - data routing by family: CNN archs (the paper's Table-2 nets) feed from
    ``ImagePipeline`` in the paper's shared-queue mode (each batch lane
    takes every B-th sample of a per-epoch permutation — no static split),
    token archs from ``TokenPipeline``;
  - checkpoint/restart: atomic keep-N checkpoints, auto-resume from latest,
    deterministic data pipeline keyed by step (resume == replay, any K);
  - pluggable sync strategies (train/sync.py registry: bsp | chaos |
    localsgd; --staleness picks chaos' τ, --layerwise the paper's
    per-layer update rule) — every strategy threads its sync state
    through the scan carry;
  - WORKER MESH (--workers N, DESIGN.md §4): the superstep scan runs inside
    shard_map over a 1-D worker mesh (the paper's Phi threads); each worker
    consumes its contiguous shard of the shared-queue batch and the sync
    mode's collectives ride the named worker axis.  bsp/chaos updates are
    bit-exact for ANY worker count dividing --logical-shards, so their
    checkpoints are worker-count-invariant (resume on fewer/more workers);
  - straggler watchdog: per-superstep wall-time z-score detection with a
    bounded flag log and a window matched to superstep granularity;
  - elastic re-meshing: on restore, arrays are placed under the *current*
    mesh's shardings, so a job can come back on fewer/more chips;
  - preemption simulation via --die-at-step (used by the fault-tolerance
    integration test); checkpoints, logs, and the simulated death all land
    on superstep boundaries.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import queue
import signal
import statistics
import sys
import threading
import time
from collections import deque

import jax
import numpy as np

import repro.configs as C
from repro.checkpoint.manager import CheckpointManager
from repro.core.chaos import SyncConfig
from repro.core.types import WorkerConfig
from repro.data.pipeline import ImagePipeline, TokenPipeline
from repro.launch.elastic import ResizeController
from repro.launch.faults import FaultPlan
from repro.launch.mesh import make_host_mesh
from repro.obs import JsonlSink, MetricsBus, Tracer
from repro.obs import trace as obs_trace
from repro.train.step import (init_train_state, init_worker_state,
                              make_optimizer, make_superstep,
                              make_worker_superstep)
from repro.train.sync import get_strategy, sync_modes

#: synthetic-MNIST pool size for CNN runs (offline container, DESIGN.md §6)
CNN_DATASET_SIZE = 4096


class StragglerWatchdog:
    """Flags supersteps slower than mean + z*std over a sliding window.

    The window adapts to superstep granularity — one observation covers K
    steps, so the window shrinks to keep a roughly constant ~200-step
    horizon (min 8 observations) — and ``flagged`` is a bounded deque so a
    long-running job cannot leak memory through its own diagnostics.

    The first ``warmup`` observations are discarded entirely: they carry
    jit-compile time (and the first donated-buffer re-trace, so TWO of
    them), which would both poison the window's variance (a multi-second
    outlier hides any real straggler for the window's whole lifetime) and
    be flagged as a phantom straggler itself.  The driver builds a FRESH
    watchdog after an elastic resize for the same reason — a new mesh
    recompiles and retimes.

    Every observation (including warmup — a 5-second compile is exactly
    what you want visible on the timeline) is exported to the obs layer
    when one is attached: a ``watchdog/superstep_s`` gauge + histogram on
    the metrics bus, a Perfetto counter track on the tracer — so a stall
    shows up in the trace BEFORE any eviction fires, not only as its
    after-the-fact ResizeOutcome row.
    """

    def __init__(self, window: int | None = None, z: float = 3.0,
                 superstep: int = 1, max_flags: int = 64, warmup: int = 2,
                 bus: MetricsBus | None = None, tracer: Tracer | None = None):
        if window is None:
            window = max(8, 200 // max(superstep, 1))
        self.times: deque = deque(maxlen=window)
        self.window = window
        self.z = z
        self.flagged: deque = deque(maxlen=max_flags)
        self.warmup = warmup
        self.bus = bus
        self.tracer = tracer

    def observe(self, step: int, dt: float) -> bool:
        """Record one superstep wall time; True when it was flagged as a
        straggler (the driver's --evict-stragglers feeds this verdict to
        the elastic ResizeController as a membership event)."""
        if self.bus is not None:
            self.bus.gauge("watchdog/superstep_s", dt)
            self.bus.observe("watchdog/superstep_s", dt)
            self.bus.series("watchdog/superstep_s", step, dt)
        if self.tracer is not None:
            self.tracer.counter("watchdog/superstep_s", dt)
        if self.warmup > 0:
            self.warmup -= 1
            return False
        straggled = False
        # need a filled-enough window before z-scoring; never require more
        # samples than the window can hold (large K shrinks it below 10)
        if len(self.times) >= min(10, self.times.maxlen):
            mu = statistics.fmean(self.times)
            sd = statistics.pstdev(self.times) or 1e-9
            if dt > mu + self.z * sd:
                straggled = True
                self.flagged.append((step, dt, mu))
                if self.bus is not None:
                    self.bus.event("straggler", step=step, dt_s=dt,
                                   mean_s=mu)
                if self.tracer is not None:
                    self.tracer.instant("straggler", step=step, dt_s=dt,
                                        mean_s=mu)
                print(f"[watchdog] superstep ending at {step} straggled: "
                      f"{dt * 1e3:.1f}ms vs mean {mu * 1e3:.1f}ms",
                      flush=True)
        self.times.append(dt)
        return straggled


def make_pipeline(cfg, batch: int, seq: int, seed: int = 0):
    """Data pipeline for the arch family: CNN -> ImagePipeline with the
    paper's shared-queue worker semantics; everything else -> TokenPipeline."""
    if cfg.family == "cnn":
        from repro.data.mnist import make_dataset
        imgs, labels = make_dataset(CNN_DATASET_SIZE, seed=seed)
        return ImagePipeline(imgs, labels, batch=batch, seed=seed,
                             sample_mode="queue")
    return TokenPipeline(cfg.vocab_size, batch, seq, seed=seed)


def put_worker_sharded(pipe, start: int, k: int, mesh, worker: WorkerConfig):
    """Assemble the global stacked (K, B, ...) superstep batch worker-shard
    by worker-shard: worker w's device receives exactly
    ``pipe.worker_superstep_at(start, k, N, w)`` (its contiguous lanes of
    the shared queue), and the shards are stitched into one global array
    sharded P(None, workers) over the batch dim — in a real multi-host run
    each host would build only its own shard."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.data.pipeline import worker_slice

    n = worker.workers
    # build the global stacked batch ONCE and slice per worker (slicing is
    # what worker_superstep_at does; rebuilding it N times would put O(N)
    # redundant host work on the prefetch hot path)
    stacked = pipe.superstep_at(start, k)
    b = next(iter(stacked.values())).shape[1]
    shards = [worker_slice(stacked, b, n, w) for w in range(n)]
    sharding = NamedSharding(mesh, P(None, worker.axis))
    devices = list(mesh.devices.flat)
    out = {}
    for key in shards[0]:
        arrs = [jax.device_put(s[key], d) for s, d in zip(shards, devices)]
        shp = shards[0][key].shape
        gshape = (shp[0], shp[1] * n) + shp[2:]
        out[key] = jax.make_array_from_single_device_arrays(
            gshape, sharding, arrs)
    return out


class PrefetchFeed:
    """Double-buffered async host->device feed.

    A daemon thread walks the superstep schedule, builds each stacked
    (K, B, ...) batch on the host, and ``jax.device_put``s it while the
    main thread's current superstep is still computing; queue depth 2 is
    classic double buffering (one in flight, one ready).  ``put`` overrides
    the host->device transfer (the worker route shards each superstep
    batch over the worker mesh, ``put_worker_sharded``).
    """

    def __init__(self, pipe, chunks, depth: int = 2, put=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._error: BaseException | None = None
        self._stopped = False
        self._put = put or (lambda p, s, k: jax.device_put(
            p.superstep_at(s, k)))
        self._thread = threading.Thread(
            target=self._produce, args=(pipe, list(chunks)), daemon=True)
        self._thread.start()

    def _produce(self, pipe, chunks):
        try:
            for start, k in chunks:
                if self._stopped:
                    return
                batch = self._put(pipe, start, k)
                self._q.put((start, k, batch))
        except BaseException as e:  # surface in the consumer, never hang it
            self._error = e
        finally:
            self._q.put(None)

    def stop(self):
        """Abandon the feed mid-schedule (elastic resize rebuilds it for
        the new mesh): drain the queue so a producer blocked in ``put``
        wakes up, sees the flag, and exits."""
        self._stopped = True
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=10)

    def __iter__(self):
        while True:
            item = self._q.get()
            if item is None:
                if self._error is not None:
                    raise RuntimeError("prefetch feed failed") from self._error
                return
            yield item


def superstep_schedule(start: int, steps: int, k: int):
    """[(chunk_start, chunk_len)] covering [start, steps) in K-step chunks
    (the final chunk may be shorter)."""
    return [(s, min(k, steps - s)) for s in range(start, steps, max(k, 1))]


def train(arch: str, steps: int, sync_mode: str = "bsp", batch: int = 8,
          seq: int = 256, ckpt_dir: str | None = None,
          ckpt_every: int = 50, die_at_step: int | None = None,
          base_lr: float = 3e-4, compress: bool = False,
          log_every: int = 10, smoke: bool = True, superstep: int = 1,
          use_kernel: bool = False, workers: int | None = None,
          logical_shards: int = 8, staleness: int = 1,
          layerwise: bool = False, optim: str = "auto",
          ring_dtype: str | None = None, inject: str | None = None,
          inject_seed: int = 0, metrics_out: str | None = None,
          evict_stragglers: bool = False, readmit_after: int | None = None,
          collective_delay: float = 0.0, interleave: bool = False,
          micro_batches: int | None = None,
          layer_chunk: int | None = None, trace_out: str | None = None,
          metrics_interval: int = 0, metrics_bus: MetricsBus | None = None):
    if superstep < 1:
        raise ValueError(f"superstep must be >= 1, got {superstep}")
    # -- observability (DESIGN.md §11) ------------------------------------
    # The bus is ALWAYS present (it replaced the ad-hoc loss_map / metrics
    # dict — per-step cost is one dict store); the tracer only when asked.
    # set_tracer BEFORE building any step function: the worker-mesh bucket
    # paths consult the global at build time, so with no tracer the
    # compiled graphs are byte-identical to a no-obs build.
    bus = metrics_bus if metrics_bus is not None else MetricsBus()
    if bus.sink is None and metrics_interval > 0 and metrics_out:
        bus.sink = JsonlSink(metrics_out + ".jsonl")
    tracer = Tracer("train") if trace_out else None
    prev_tracer = obs_trace.set_tracer(tracer) if tracer else None
    try:
        return _train(arch, steps, sync_mode, batch, seq, ckpt_dir,
                      ckpt_every, die_at_step, base_lr, compress, log_every,
                      smoke, superstep, use_kernel, workers, logical_shards,
                      staleness, layerwise, optim, ring_dtype, inject,
                      inject_seed, metrics_out, evict_stragglers,
                      readmit_after, collective_delay, interleave,
                      micro_batches, layer_chunk, metrics_interval, bus,
                      tracer)
    finally:
        if tracer is not None:
            obs_trace.set_tracer(prev_tracer)
            tracer.write(trace_out)
        bus.close()


def _train(arch, steps, sync_mode, batch, seq, ckpt_dir, ckpt_every,
           die_at_step, base_lr, compress, log_every, smoke, superstep,
           use_kernel, workers, logical_shards, staleness, layerwise, optim,
           ring_dtype, inject, inject_seed, metrics_out, evict_stragglers,
           readmit_after, collective_delay, interleave, micro_batches,
           layer_chunk, metrics_interval, bus, tracer):
    plan = FaultPlan.from_spec(inject, seed=inject_seed)
    cfg = C.smoke(arch) if smoke else C.get(arch)
    if use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=True)
    if micro_batches is not None:
        cfg = dataclasses.replace(cfg, micro_batches=micro_batches)
    if layer_chunk is not None:
        cfg = dataclasses.replace(cfg, layer_chunk=layer_chunk)
    optimizer = make_optimizer(cfg, base_lr=base_lr, total_steps=steps,
                               kind=optim)
    put = None
    controller = None
    if workers is not None:
        # CHAOS worker-mesh route (DESIGN.md §4): the superstep scan runs
        # inside shard_map over a 1-D worker mesh; each worker consumes its
        # contiguous shard of the shared-queue batch, and the strategy's
        # collectives thread over the named worker axis.  N=1 runs the SAME
        # code path, so semantics never depend on how many devices back it.
        worker = WorkerConfig(workers=workers, logical_shards=logical_shards)
        worker.validate_batch(batch)
        mesh = make_host_mesh(workers)
        sync = SyncConfig(mode=sync_mode, compress=compress,
                          axis_name=worker.axis, staleness=staleness,
                          layerwise=layerwise, ring_dtype=ring_dtype,
                          collective_delay_ns_per_byte=collective_delay,
                          interleave=interleave)
        super_fn = make_worker_superstep(cfg, sync, worker, mesh, optimizer)
        state = init_worker_state(cfg, jax.random.key(0), sync, worker,
                                  optimizer)
        put = lambda p, s, k: put_worker_sharded(p, s, k, mesh, worker)
        controller = ResizeController(cfg, sync, optimizer, worker, mesh,
                                      fault=plan, readmit_after=readmit_after)
        try:  # SIGUSR1 = the scheduler's preemption warning: shed a worker
            signal.signal(signal.SIGUSR1, lambda *_: controller.request(
                controller.worker.workers - 1, "SIGUSR1 preemption warning"))
        except ValueError:
            pass  # not the main thread (in-process harness) — skip the hook
        print(f"[train] worker mesh: {workers} worker(s) x "
              f"{worker.shards_per_worker} shard(s), sync={sync_mode} "
              f"({get_strategy(sync).checkpoint_layout()})", flush=True)
    else:
        if plan is not None and any(e.kind == "kill" for e in plan.events):
            print("[train] NOTE: --inject kill@... is a worker-membership "
                  "event; without --workers there is no mesh to resize, so "
                  "kill events are ignored on this route", flush=True)
        sync = SyncConfig(mode=sync_mode, compress=compress,
                          staleness=staleness, layerwise=layerwise,
                          ring_dtype=ring_dtype,
                          collective_delay_ns_per_byte=collective_delay,
                          interleave=interleave)
        # K=1 is a length-1 scan: every run dispatches through the same scan
        # body, so mixing K across runs/resumes cannot change the numerics
        super_fn = jax.jit(make_superstep(cfg, sync, optimizer),
                           donate_argnums=(0,))
        state = init_train_state(cfg, jax.random.key(0), sync, optimizer)
    pipe = make_pipeline(cfg, batch, seq)

    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep_n=3, fault=plan)
        if controller is not None:
            controller.ckpt_mgr = mgr  # the resize ladder's restore rung
        if mgr.latest_step() is not None:
            state, start = mgr.restore(state)
            print(f"[train] resumed from step {start}", flush=True)

    watchdog = StragglerWatchdog(superstep=superstep, bus=bus, tracer=tracer)
    # losses live on the bus as a step-keyed series: an elastic
    # ckpt-restore rung may REPLAY a few steps, and replayed entries
    # overwrite their originals (bit-exactly for worker-count-invariant
    # strategies) instead of duplicating
    saved_at = None
    next_start = start
    faults_seen = 0
    work_s, work_steps = 0.0, 0
    while next_start < steps:
        feed = PrefetchFeed(pipe,
                            superstep_schedule(next_start, steps, superstep),
                            put=put)
        resize_request = None
        for s0, k, dev_batch in feed:
            t0 = time.time()
            if tracer is not None:
                with tracer.span("superstep", step_start=s0, k=k):
                    state, metrics = super_fn(state, dev_batch)
                    # ONE host sync per K steps: the (K,) loss vector —
                    # inside the span so it covers device time, not just
                    # the async dispatch
                    loss_vec = np.asarray(metrics["loss"])
            else:
                state, metrics = super_fn(state, dev_batch)
                loss_vec = np.asarray(metrics["loss"])
            end = s0 + k
            for t in range(s0, end):
                bus.series("train/loss", t, float(loss_vec[t - s0]))
            if plan is not None:
                plan.stall(end)  # inside the watchdog's timed window
            dt = time.time() - t0
            straggled = watchdog.observe(end, dt)
            work_s += dt
            work_steps += k
            bus.gauge("train/steps_per_s", work_steps / max(work_s, 1e-9))
            bus.gauge("train/loss", float(loss_vec[-1]))
            if plan is not None and len(plan.log) > faults_seen:
                for f in plan.log[faults_seen:]:
                    bus.event("fault", **f)
                    if tracer is not None:
                        tracer.instant("fault", **f)
                faults_seen = len(plan.log)
            if metrics_interval > 0 and (
                    end // metrics_interval > s0 // metrics_interval):
                if bus.sink is not None:
                    bus.flush(end)
                else:
                    print(f"[obs] step {end} "
                          + json.dumps(bus.summary()["gauges"]), flush=True)
            for t in range(s0, end):
                if t % log_every == 0:
                    print(f"[train {arch} sync={sync_mode}] step {t} "
                          f"loss={loss_vec[t - s0]:.4f}", flush=True)
            if mgr and end // ckpt_every > s0 // ckpt_every:
                with obs_trace.span("checkpoint", step=end):
                    mgr.save(end, state, blocking=False)
                saved_at = end
            if die_at_step is not None and end >= die_at_step:
                if mgr:
                    mgr.wait()
                print(f"[train] simulated preemption at step {end}",
                      flush=True)
                sys.exit(17)
            next_start = end
            # membership-change events apply at superstep boundaries: the
            # in-flight superstep is already drained here (DESIGN.md §7)
            if controller is not None and end < steps:
                if plan is not None:
                    target = plan.membership_event(
                        end, controller.worker.workers)
                    if target is not None:
                        controller.request(target, "injected worker-kill")
                if evict_stragglers and straggled:
                    controller.request(
                        controller.worker.workers - 1,
                        f"straggler verdict at step {end}")
                controller.observe_boundary(straggled)
                resize_request = controller.take_pending()
                if resize_request is not None:
                    break
        if resize_request is None:
            break
        feed.stop()
        if mgr:
            mgr.wait()  # never race an async save with the restore rung
        target, reason = resize_request
        with obs_trace.span("resize", target=target, reason=reason,
                            at_step=next_start):
            state, new_super_fn, outcome = controller.resize(
                state, target, next_start, reason=reason)
        bus.event("resize", **outcome.as_dict())
        bus.gauge("train/workers", controller.worker.workers)
        if new_super_fn is not None:
            super_fn = new_super_fn
            put = (lambda p, s, k, m=controller.mesh, w=controller.worker:
                   put_worker_sharded(p, s, k, m, w))
            # new mesh => recompile + new timing regime: stale window stats
            # would flag the first post-resize superstep as a straggler
            watchdog = StragglerWatchdog(superstep=superstep, bus=bus,
                                         tracer=tracer)
        if outcome.restart_step is not None:
            next_start = outcome.restart_step  # replay from the checkpoint

    losses = bus.series_sorted("train/loss")
    if mgr:
        if saved_at == steps:
            mgr.wait()
        else:
            with obs_trace.span("checkpoint", step=steps):
                mgr.save(steps, state, blocking=True)
    if plan is not None and len(plan.log) > faults_seen:
        for f in plan.log[faults_seen:]:
            bus.event("fault", **f)
    if metrics_out:
        bus.write_metrics_out(metrics_out, arch=arch, sync=sync_mode,
                              steps=steps,
                              workers_final=(controller.worker.workers
                                             if controller else None))
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--sync", default="bsp", choices=sync_modes(),
                    help="synchronization strategy (train/sync.py registry)")
    ap.add_argument("--staleness", type=int, default=1,
                    help="staleness tau: chaos counts steps (0 degenerates "
                         "exactly to bsp — bit-exact, same checkpoints); "
                         "localsgd counts boundaries (0 = the blocking "
                         "K-step average, >=1 the tau-ring stale "
                         "corrections, DESIGN.md section 8)")
    ap.add_argument("--layerwise", action="store_true",
                    help="per-bucket non-instant updates during backprop "
                         "(paper update rule via the ParamBuckets tape; "
                         "any family/optimizer, composes with --workers "
                         "and --compress)")
    ap.add_argument("--optim", default="auto",
                    choices=["auto", "sgd", "momentum", "adamw"],
                    help="optimizer override (auto = family default: CNN "
                         "-> the paper's plain SGD, else adamw)")
    ap.add_argument("--ring-dtype", default=None,
                    choices=["bfloat16", "float32"],
                    help="chaos staleness-ring slot dtype (default: param "
                         "dtype); bfloat16 halves the tau x params ring "
                         "memory via the compression cast")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--superstep", type=int, default=1,
                    help="steps per compiled scan dispatch (K)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route the CNN hot path through the Pallas kernels")
    ap.add_argument("--workers", type=int, default=None,
                    help="CHAOS worker-mesh route: N worker instances over "
                         "a 1-D device mesh (needs N visible devices; force "
                         "host devices with XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N)")
    ap.add_argument("--logical-shards", type=int, default=8,
                    help="fixed micro-shard count of the global batch on "
                         "the worker route; any --workers dividing it "
                         "computes bit-identical bsp/chaos updates")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--die-at-step", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--inject", default=None,
                    help="deterministic fault-injection spec "
                         "(launch/faults.py), e.g. "
                         "'kill@6:to=3,torn@8,io@restore:times=2'")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for the fault plan's randomness (unspecified "
                         "torn fractions)")
    ap.add_argument("--metrics-out", default=None,
                    help="write a JSON artifact with the per-step loss "
                         "sequence, resize outcomes, and fired faults "
                         "(CI / test assertions; composed by the obs "
                         "metrics bus, DESIGN.md §11)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace/Perfetto trace.json (+ "
                         ".jsonl) with superstep/checkpoint/resize spans "
                         "and, on the layerwise worker mesh, per-bucket "
                         "exchange issue/gate spans (DESIGN.md §11)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="emit a metrics-bus snapshot every N steps — to "
                         "<metrics-out>.jsonl when --metrics-out is set, "
                         "else to stdout; 0 disables")
    ap.add_argument("--evict-stragglers", action="store_true",
                    help="feed straggler-watchdog verdicts to the elastic "
                         "resize controller (shed one worker per verdict)")
    ap.add_argument("--readmit-after", type=int, default=None,
                    help="re-admit a straggler-evicted worker after this "
                         "many consecutive clean supersteps (probation "
                         "window; a straggle during probation resets it)")
    ap.add_argument("--collective-delay", type=float, default=0.0,
                    help="overlap harness (DESIGN.md §8): inject this many "
                         "nanoseconds of latency per byte into every "
                         "explicit worker-mesh collective; 0 leaves the "
                         "compiled graph untouched")
    ap.add_argument("--interleave", action="store_true",
                    help="layerwise worker mesh: fire each bucket's "
                         "exchange during backprop the moment that layer's "
                         "gradient is produced (DESIGN.md §8) instead of "
                         "collect-then-walk; ~1-ulp vs the batched pin")
    ap.add_argument("--micro-batches", type=int, default=None,
                    help="override the arch's micro-batch accumulation "
                         "count (single-instance route; composes with "
                         "--layerwise via the bucket-granular accumulator)")
    ap.add_argument("--layer-chunk", type=int, default=None,
                    help="LM layer-stack chunk size (DESIGN.md §10): split "
                         "the scanned layer stack into n_layers/c per-chunk "
                         "param buckets so --layerwise/--interleave exchange "
                         "at chunk granularity; 0 keeps the single-stack "
                         "scan layout, must divide n_layers")
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.sync, args.batch, args.seq,
                      args.ckpt_dir, args.ckpt_every, args.die_at_step,
                      args.lr, args.compress, smoke=not args.full_config,
                      superstep=args.superstep, use_kernel=args.use_kernel,
                      workers=args.workers,
                      logical_shards=args.logical_shards,
                      staleness=args.staleness, layerwise=args.layerwise,
                      optim=args.optim, ring_dtype=args.ring_dtype,
                      inject=args.inject, inject_seed=args.inject_seed,
                      metrics_out=args.metrics_out,
                      evict_stragglers=args.evict_stragglers,
                      readmit_after=args.readmit_after,
                      collective_delay=args.collective_delay,
                      interleave=args.interleave,
                      micro_batches=args.micro_batches,
                      layer_chunk=args.layer_chunk,
                      trace_out=args.trace_out,
                      metrics_interval=args.metrics_interval)
    print(f"[train] done: first-10 mean {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
