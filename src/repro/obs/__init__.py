"""Observability subsystem (DESIGN.md §11): span tracing with Perfetto
export (`obs.trace`) and the counters/gauges/histograms metrics bus
(`obs.metrics`).  Zero-cost when unused: no tracer installed ⇒ nothing is
inserted into any compiled graph or hot loop."""
from repro.obs.metrics import JsonlSink, MetricsBus
from repro.obs.trace import Tracer, get_tracer, set_tracer, span

__all__ = ["JsonlSink", "MetricsBus", "Tracer", "get_tracer", "set_tracer",
           "span"]
