"""Metrics bus (DESIGN.md §11): a small counters/gauges/histograms registry
with step-keyed series, typed event logs, a pluggable JSONL sink, and the
``summary()`` tests assert against.

Instruments:

- **counter** — monotonically increasing int (dispatches, tokens, faults);
- **gauge** — last-value float (workers, slot occupancy, queue depth,
  steps/sec, per-superstep wall time);
- **histogram** — bounded reservoir with count/mean/min/max/p50/p99
  (TTFT, TPOT, superstep wall times);
- **series** — float keyed by STEP with overwrite semantics: an elastic
  checkpoint-restore rung replays steps, and the replayed value must
  overwrite its original (bit-exactly for worker-count-invariant
  strategies) instead of duplicating — same contract the driver's old
  ``loss_map`` had;
- **event** — append-only dict log per name (resize outcomes, fired
  faults, stragglers).

``write_metrics_out`` emits the exact PR-6 ``--metrics-out`` schema
(``arch``/``sync``/``steps``/``losses``/``resizes``/``faults``/
``workers_final``) from the bus's instruments — the CI preemption smoke
asserts on those keys, so the driver now has ONE metrics path instead of
an ad-hoc dict next to the bus.
"""
from __future__ import annotations

import json
from typing import Optional


class JsonlSink:
    """Appends one JSON object per ``write()`` to ``path``."""

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def write(self, record: dict):
        if self._f is None:
            self._f = open(self.path, "w")
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None


class _Histogram:
    __slots__ = ("values", "count", "total", "cap")

    def __init__(self, cap: int = 4096):
        self.values: list = []
        self.count = 0
        self.total = 0.0
        self.cap = cap

    def observe(self, v: float):
        self.count += 1
        self.total += v
        if len(self.values) < self.cap:   # bounded: summary stays O(cap)
            self.values.append(v)

    def stats(self) -> dict:
        if not self.values:
            return {"count": 0}
        s = sorted(self.values)
        n = len(s)
        return {"count": self.count, "mean": self.total / self.count,
                "min": s[0], "max": s[-1],
                "p50": s[n // 2], "p99": s[min(n - 1, int(n * 0.99))]}


class MetricsBus:
    """One registry per run.  All mutation is plain dict/list work — cheap
    enough for the driver's per-superstep loop (the ≤2%-overhead budget is
    pinned by tests/test_obs.py)."""

    def __init__(self, sink: Optional[JsonlSink] = None):
        self.sink = sink
        self._counters: dict = {}
        self._gauges: dict = {}
        self._hists: dict = {}
        self._series: dict = {}     # name -> {step: value}
        self._events: dict = {}     # name -> [dict, ...]

    # -- instruments --------------------------------------------------------
    def counter(self, name: str, inc: int = 1):
        self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float):
        self._gauges[name] = float(value)

    def observe(self, name: str, value: float):
        self._hists.setdefault(name, _Histogram()).observe(float(value))

    def series(self, name: str, step: int, value: float):
        self._series.setdefault(name, {})[int(step)] = float(value)

    def event(self, name: str, **fields):
        self._events.setdefault(name, []).append(fields)

    # -- reads --------------------------------------------------------------
    def series_sorted(self, name: str) -> list:
        d = self._series.get(name, {})
        return [d[k] for k in sorted(d)]

    def events(self, name: str) -> list:
        return self._events.get(name, [])

    def summary(self) -> dict:
        return {
            "counters": dict(self._counters),
            "gauges": dict(self._gauges),
            "histograms": {k: h.stats() for k, h in self._hists.items()},
            "series": {k: {"steps": sorted(d), "values": self.series_sorted(k)}
                       for k, d in self._series.items()},
            "events": {k: list(v) for k, v in self._events.items()},
        }

    # -- sink ---------------------------------------------------------------
    def flush(self, step: Optional[int] = None):
        """Write one snapshot line (counters + gauges + histogram stats) to
        the sink; no-op without one.  The driver calls this every
        ``--metrics-interval`` steps."""
        if self.sink is None:
            return
        self.sink.write({"step": step, "counters": dict(self._counters),
                         "gauges": dict(self._gauges),
                         "histograms": {k: h.stats()
                                        for k, h in self._hists.items()}})

    def close(self):
        if self.sink is not None:
            self.sink.close()

    # -- the PR-6 --metrics-out document ------------------------------------
    def write_metrics_out(self, path: str, *, arch: str, sync: str,
                          steps: int, workers_final):
        """Compose the driver's metrics artifact from the bus: ``losses``
        from the ``train/loss`` series (step-keyed, replay-overwritten),
        ``resizes``/``faults`` from the event logs, verbatim keys the CI
        preemption smoke asserts on."""
        with open(path, "w") as f:
            json.dump({
                "arch": arch, "sync": sync, "steps": steps,
                "losses": self.series_sorted("train/loss"),
                "resizes": self.events("resize"),
                "faults": self.events("fault"),
                "workers_final": workers_final,
            }, f, indent=1)
        print(f"[obs] wrote metrics to {path}", flush=True)
