"""Span tracer (DESIGN.md §11): host-side nestable spans + in-dispatch
per-bucket exchange stamps, exported as Chrome-trace/Perfetto ``trace.json``
and a flat JSONL.

Two event sources share one clock (`core/chaos.py`'s deadline epoch, so
trace timestamps and injected-latency deadlines line up exactly):

- **host spans** — `Tracer.span(...)` context manager around driver-side
  phases (``superstep``, ``prefill``, ``decode``, ``checkpoint``,
  ``resize``, ``autotune``).  Cost: one ``time.monotonic()`` pair + a dict
  append; nesting is Perfetto's native stacking of overlapping complete
  events on one track.

- **device stamps** — ``bucket_issue``/``bucket_gate`` reuse the PR-7
  ``pure_callback`` deadline machinery (``core/chaos.py``): the issue
  callback fires the moment a bucket's gradient exists mid-backward and
  returns the f32 deadline token (``now + delay_ms``, ms since the chaos
  epoch — the SAME token ``delay_gate`` consumes), recording the issue
  time; the gate callback sleeps the deadline remainder (0 when no latency
  is injected) and records ``[gate_start, gate_end]`` plus the residual
  actually slept.  With ``delay_ms > 0`` the pair IS the injection — the
  traced path never double-charges.  ``finalize()`` pairs the i-th issue
  with the i-th gate per (bucket, worker) — one issue and one gate per
  step, steps are sequential inside the scan — yielding per-bucket
  ``exchange/<bucket>`` spans (issue → gate end, the in-flight window) and
  ``exchange_wait/<bucket>`` spans (the gate's critical-path sleep, whose
  per-step sum is the measured exchange cost BENCH_overlap.json calls
  ``exchange_us``).

Track layout (Perfetto): pid per subsystem (``train`` / ``serve`` /
``bench``), tid 0 = the host thread (``driver`` / ``engine``), tid 1+ one
per worker (``worker0..N``) or slot (``slot0..S``).  Span args carry
bytes, bucket name, τ, and injected delay.

When no tracer is installed (``get_tracer() is None``) nothing is inserted
anywhere — the compiled graph, and therefore every bit-exactness pin, is
byte-identical to a no-obs build.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chaos import _EPOCH, _first_scalar


def _now_us() -> float:
    """Microseconds since the chaos deadline epoch (shared clock)."""
    return (time.monotonic() - _EPOCH) * 1e6


class Tracer:
    """Collects events in memory; ``write()`` exports trace.json + .jsonl.

    Thread-safe: host spans come from the driver thread, device stamps from
    XLA host-callback threads (one per forced-host device), serve spans
    from the engine loop.
    """

    def __init__(self, process: str = "train"):
        self.default_process = process
        self._lock = threading.Lock()
        self._events: list = []          # chrome "X"/"i"/"C" dicts
        self._device: list = []          # raw issue/gate stamp records
        self._tag_args: dict = {}        # bucket tag -> static args
        self._pids: dict = {}            # process name -> pid
        self._tids: dict = {}            # (pid, thread name) -> tid

    # -- track bookkeeping --------------------------------------------------
    def _track(self, process: Optional[str], thread: str):
        process = process or self.default_process
        with self._lock:
            pid = self._pids.setdefault(process, len(self._pids) + 1)
            key = (pid, thread)
            if key not in self._tids:
                used = [t for (p, _), t in self._tids.items() if p == pid]
                self._tids[key] = (max(used) + 1) if used else 0
            return pid, self._tids[key]

    # -- host spans ---------------------------------------------------------
    @contextmanager
    def span(self, name: str, *, process: Optional[str] = None,
             thread: str = "driver", cat: str = "host", **args):
        t0 = _now_us()
        try:
            yield self
        finally:
            t1 = _now_us()
            pid, tid = self._track(process, thread)
            ev = {"name": name, "ph": "X", "ts": t0, "dur": t1 - t0,
                  "pid": pid, "tid": tid, "cat": cat}
            if args:
                ev["args"] = args
            with self._lock:
                self._events.append(ev)

    def complete(self, name: str, t0_us: float, t1_us: float, *,
                 process: Optional[str] = None, thread: str = "driver",
                 cat: str = "host", **args):
        """Record a span from explicit ``_now_us()``-clock endpoints (for
        lifecycles that open in one call and close in another, e.g. a serve
        request's admit→evict window)."""
        pid, tid = self._track(process, thread)
        ev = {"name": name, "ph": "X", "ts": t0_us, "dur": t1_us - t0_us,
              "pid": pid, "tid": tid, "cat": cat}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, *, process: Optional[str] = None,
                thread: str = "driver", cat: str = "host", **args):
        pid, tid = self._track(process, thread)
        ev = {"name": name, "ph": "i", "s": "t", "ts": _now_us(),
              "pid": pid, "tid": tid, "cat": cat}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, value: float, *,
                process: Optional[str] = None, thread: str = "driver"):
        """Chrome counter event — renders as a value track in Perfetto
        (e.g. per-superstep wall time, so a straggler is visible as a spike
        before any eviction fires)."""
        pid, tid = self._track(process, thread)
        with self._lock:
            self._events.append({"name": name, "ph": "C", "ts": _now_us(),
                                 "pid": pid, "tid": tid,
                                 "args": {"value": float(value)}})

    def now_us(self) -> float:
        return _now_us()

    # -- in-dispatch device stamps (pure_callback, chaos deadline clock) ----
    def _issue_cb(self, tag, widx, _anchor, delay_ms):
        t = _now_us()
        with self._lock:
            self._device.append({"tag": tag, "phase": "issue",
                                 "worker": int(widx), "t_us": t,
                                 "delay_ms": float(delay_ms)})
        # deadline token in ms since the chaos epoch — delay_gate-compatible
        return np.float32(t * 1e-3 + float(delay_ms))

    def _gate_cb(self, tag, deadline, widx, _anchor):
        t0 = _now_us()
        rem = (float(deadline) - t0 * 1e-3) * 1e-3
        if rem > 0:
            time.sleep(rem)
        t1 = _now_us()
        with self._lock:
            self._device.append({"tag": tag, "phase": "gate",
                                 "worker": int(widx), "t_us": t0,
                                 "t_end_us": t1,
                                 "slept_ms": max(rem, 0.0) * 1e3})
        return np.float32(0.0)

    def bucket_issue(self, anchor_tree, tag: str, delay_ms=0.0, worker=None,
                     args: Optional[dict] = None):
        """Issue stamp: fires when ``anchor_tree``'s first leaf is ready
        (the exchange's issue point, mid-backward).  Returns the f32
        deadline token, exactly like ``core.chaos.delay_start`` — with
        ``delay_ms > 0`` the stamped deadline doubles as the injected
        collective latency.  ``args`` (static per tag: bytes, τ, ...) land
        on the exported spans."""
        if args:
            with self._lock:
                self._tag_args.setdefault(tag, dict(args))
        w = jnp.asarray(0 if worker is None else worker, jnp.int32)
        return jax.pure_callback(
            partial(self._issue_cb, tag),
            jax.ShapeDtypeStruct((), np.float32),
            w, _first_scalar(anchor_tree),
            jnp.asarray(delay_ms, jnp.float32))

    def bucket_gate(self, tree, token, anchor_tree, tag: str, worker=None):
        """Gate stamp: once ``anchor_tree`` is ready, sleep ``token``'s
        deadline remainder (0 when nothing was injected), record the gate
        window, and pass ``tree`` through value-unchanged (the gate's 0.0
        is added to the first leaf so XLA cannot eliminate or reorder it —
        ``core.chaos.delay_gate``'s tie)."""
        w = jnp.asarray(0 if worker is None else worker, jnp.int32)
        z = jax.pure_callback(
            partial(self._gate_cb, tag),
            jax.ShapeDtypeStruct((), np.float32),
            token, w, _first_scalar(anchor_tree))
        leaves, treedef = jax.tree.flatten(tree)
        leaves = [leaves[0] + z.astype(leaves[0].dtype)] + leaves[1:]
        return jax.tree.unflatten(treedef, leaves)

    # -- assembly / export --------------------------------------------------
    def finalize(self) -> list:
        """Pair issue/gate stamps into ``exchange``/``exchange_wait`` spans
        on per-worker tracks; returns (and caches into the event list via
        ``to_chrome``) the chrome dicts."""
        by_key: dict = {}
        with self._lock:
            device = list(self._device)
        for rec in device:
            by_key.setdefault((rec["tag"], rec["worker"]),
                              {"issue": [], "gate": []})[rec["phase"]] \
                .append(rec)
        out = []
        for (tag, worker), recs in sorted(by_key.items()):
            issues = sorted(recs["issue"], key=lambda r: r["t_us"])
            gates = sorted(recs["gate"], key=lambda r: r["t_us"])
            pid, tid = self._track(None, f"worker{worker}")
            static = self._tag_args.get(tag, {})
            for i, g in zip(issues, gates):
                args = {"bucket": tag, "worker": worker,
                        "slept_ms": g["slept_ms"],
                        "delay_ms": i["delay_ms"], **static}
                out.append({"name": f"exchange/{tag}", "ph": "X",
                            "ts": i["t_us"],
                            "dur": g["t_end_us"] - i["t_us"],
                            "pid": pid, "tid": tid, "cat": "exchange",
                            "args": args})
                out.append({"name": f"exchange_wait/{tag}", "ph": "X",
                            "ts": g["t_us"],
                            "dur": g["t_end_us"] - g["t_us"],
                            "pid": pid, "tid": tid, "cat": "exchange",
                            "args": args})
        return out

    def to_chrome(self) -> dict:
        device = self.finalize()     # registers worker tracks before the
        events = []                  # metadata snapshot below
        with self._lock:
            pids = dict(self._pids)
            tids = dict(self._tids)
            host = list(self._events)
        for name, pid in pids.items():
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": name}})
        for (pid, tname), tid in tids.items():
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": tname}})
        events += host + device
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str):
        """Write Chrome-trace JSON to ``path`` and a flat JSONL (one event
        per line, the log-pipeline-friendly form) next to it."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
        jsonl = path + "l" if path.endswith(".json") else path + ".jsonl"
        with open(jsonl, "w") as f:
            for ev in doc["traceEvents"]:
                f.write(json.dumps(ev) + "\n")
        print(f"[obs] wrote {len(doc['traceEvents'])} trace events to "
              f"{path} (+ {jsonl})", flush=True)


# -- module-global active tracer (build-time switch) ------------------------
_ACTIVE: Optional[Tracer] = None


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or clear, with None) the process-wide tracer.  Step builders
    consult this AT BUILD TIME: functions compiled while it is None contain
    no callbacks at all.  Returns the previous tracer."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, tracer
    return prev


def get_tracer() -> Optional[Tracer]:
    return _ACTIVE


@contextmanager
def span(name: str, **kw):
    """No-op when no tracer is installed; otherwise ``Tracer.span``."""
    t = _ACTIVE
    if t is None:
        yield None
    else:
        with t.span(name, **kw):
            yield t
