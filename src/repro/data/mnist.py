"""Deterministic synthetic MNIST (the container is offline — DESIGN.md §6).

Procedurally renders 28x28 digit glyphs from a 7x7 stroke font, applies
per-sample affine jitter + noise, pads to 29x29 (the paper's input size).
Deterministic given the seed; samples are genuinely separable-but-nontrivial
so convergence and accuracy-parity experiments (paper Result 4) are
meaningful.
"""
from __future__ import annotations

import numpy as np

# 7x5 bitmap font for digits 0-9
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}

_GLYPHS = np.stack([
    np.array([[int(c) for c in row] for row in _FONT[d]], np.float32)
    for d in range(10)])  # (10, 7, 5)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    g = _GLYPHS[digit]
    # upsample 7x5 -> 21x15 and place on 28x28 with jitter
    img = np.kron(g, np.ones((3, 3), np.float32))
    canvas = np.zeros((28, 28), np.float32)
    oy = 3 + rng.integers(-2, 3)
    ox = 6 + rng.integers(-3, 4)
    # shear: shift rows by up to +-2 px progressively
    shear = rng.uniform(-0.12, 0.12)
    out = np.zeros_like(img)
    for r in range(img.shape[0]):
        shift = int(round(shear * (r - img.shape[0] / 2)))
        out[r] = np.roll(img[r], shift)
    h, w = out.shape
    canvas[oy:oy + h, ox:ox + w] = out
    # stroke-weight variation + blur-ish noise
    canvas = np.clip(canvas * rng.uniform(0.75, 1.0), 0, 1)
    canvas += rng.normal(0, 0.08, canvas.shape).astype(np.float32)
    return np.clip(canvas, 0.0, 1.0)


def make_dataset(n: int, seed: int = 0):
    """Returns (images (n,29,29,1) float32, labels (n,) int32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    images = np.zeros((n, 29, 29, 1), np.float32)
    for i in range(n):
        img = _render(int(labels[i]), rng)
        images[i, :28, :28, 0] = img
    return images, labels


def splits(n_train: int = 2048, n_valid: int = 512, n_test: int = 512,
           seed: int = 0):
    """Train/validation/test splits (paper uses 60k/10k; tests use less)."""
    tr = make_dataset(n_train, seed)
    va = make_dataset(n_valid, seed + 1)
    te = make_dataset(n_test, seed + 2)
    return tr, va, te
