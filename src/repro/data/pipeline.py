"""Deterministic, shardable data pipelines.

- ``TokenPipeline``: synthetic LM token stream (zipfian unigram + bigram
  structure so a model can actually reduce loss), sharded per host/replica.
- ``ImagePipeline``: batches over the synthetic MNIST arrays, with the
  paper's "workers pick the next image" global-queue semantics (each worker
  takes every k-th sample — no static partitioning).
- Both support exact resume from a step counter (fault tolerance: the
  checkpoint stores the step; the pipeline is a pure function of it).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def _rng(self, step: int):
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int):
        """Deterministic batch for `step` — resume == replay."""
        rng = self._rng(step)
        B, T, V = self.batch, self.seq_len, self.vocab_size
        # zipfian unigrams with a deterministic bigram successor table:
        # makes next-token prediction learnable (loss goes below ln(V)).
        base = rng.zipf(1.3, size=(B, T)).astype(np.int64) % V
        succ = (np.arange(V) * 2654435761 + 12345) % V
        mix = rng.random((B, T)) < 0.5
        tokens = base.copy()
        tokens[:, 1:] = np.where(mix[:, 1:], succ[base[:, :-1]], base[:, 1:])
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class ImagePipeline:
    images: np.ndarray
    labels: np.ndarray
    batch: int
    seed: int = 0

    def batch_at(self, step: int):
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, len(self.images), size=self.batch)
        return {"images": self.images[idx], "labels": self.labels[idx]}

    def worker_batches(self, step: int, n_workers: int, per_worker: int):
        """Paper-style shared queue: worker w takes samples
        queue[w::n_workers] — workers that finish early simply take the
        next image; no static split (straggler-friendly)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        order = rng.permutation(len(self.images))
        need = n_workers * per_worker
        order = np.resize(order, need)
        idx = order.reshape(per_worker, n_workers).T  # w-th row: its picks
        return {"images": self.images[idx], "labels": self.labels[idx]}

    def epochs(self, n_epochs: int, n_workers: int):
        per_worker = len(self.images) // n_workers
        for ep in range(n_epochs):
            yield self.worker_batches(ep, n_workers, per_worker)
