"""Deterministic, shardable data pipelines.

- ``TokenPipeline``: synthetic LM token stream (zipfian unigram + bigram
  structure so a model can actually reduce loss), sharded per host/replica.
- ``ImagePipeline``: batches over the synthetic MNIST arrays, with the
  paper's "workers pick the next image" global-queue semantics (each worker
  takes every k-th sample — no static partitioning).
- Both support exact resume from a step counter (fault tolerance: the
  checkpoint stores the step; the pipeline is a pure function of it).
- Both produce stacked **superstep** batches — ``superstep_at(step, k)``
  returns a (k, B, ...) pytree whose slice ``i`` is bit-identical to
  ``batch_at(step + i)``, so a K-step ``lax.scan`` superstep consumes the
  exact same sample sequence as K individual steps (resume == replay
  survives any K).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _stack_batches(batches):
    """Stack a list of same-structure dict batches along a new axis 0."""
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


def worker_slice(stacked: dict, batch: int, n_workers: int, worker: int):
    """Worker w's shard of a stacked (K, B, ...) superstep batch: the
    contiguous lane range [w*B/N, (w+1)*B/N) of every step.  Concatenating
    the shards over w along axis 1 reconstructs the stacked batch exactly
    (tests/test_pipeline_sharding.py), so N workers consume the SAME global
    sample sequence as one — the paper's shared-queue semantics are
    preserved bit-for-bit at any worker count."""
    if not 0 <= worker < n_workers:
        raise ValueError(f"worker {worker} out of range [0, {n_workers})")
    if batch % n_workers != 0:
        raise ValueError(
            f"global batch {batch} must be divisible by n_workers="
            f"{n_workers} for equal worker shards")
    per = batch // n_workers
    lo = worker * per
    return {k: v[:, lo:lo + per] for k, v in stacked.items()}


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def _rng(self, step: int):
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch_at(self, step: int):
        """Deterministic batch for `step` — resume == replay."""
        rng = self._rng(step)
        B, T, V = self.batch, self.seq_len, self.vocab_size
        # zipfian unigrams with a deterministic bigram successor table:
        # makes next-token prediction learnable (loss goes below ln(V)).
        base = rng.zipf(1.3, size=(B, T)).astype(np.int64) % V
        succ = (np.arange(V) * 2654435761 + 12345) % V
        mix = rng.random((B, T)) < 0.5
        tokens = base.copy()
        tokens[:, 1:] = np.where(mix[:, 1:], succ[base[:, :-1]], base[:, 1:])
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1)
        return {"tokens": tokens, "labels": labels}

    def superstep_at(self, step: int, k: int):
        """Stacked (k, B, T) batch covering steps [step, step + k)."""
        return _stack_batches([self.batch_at(step + i) for i in range(k)])

    def worker_superstep_at(self, step: int, k: int, n_workers: int,
                            worker: int):
        """Worker ``worker``'s (k, B/N, T) shard of ``superstep_at(step, k)``
        — contiguous lanes, concat over workers == the global batch."""
        return worker_slice(self.superstep_at(step, k), self.batch,
                            n_workers, worker)


@dataclasses.dataclass
class ImagePipeline:
    images: np.ndarray
    labels: np.ndarray
    batch: int
    seed: int = 0
    #: "iid"   — each batch is an independent uniform draw (legacy default);
    #: "queue" — the paper's shared-queue semantics: per epoch one global
    #:           permutation is the queue and batch lane w acts as worker w
    #:           taking every batch-th sample (queue[w::batch]), so the
    #:           in-epoch step-t batch is the contiguous chunk
    #:           queue[t*B:(t+1)*B] — workers that finish early just take
    #:           the next image, no static split (straggler-friendly).
    sample_mode: str = "iid"
    # small LRU of (epoch, permutation) pairs — queue_batch_at is a pure
    # function of the step, so this is purely a recomputation cache
    # (superstep_at would otherwise re-permute the whole dataset K times per
    # chunk); two entries because a batch can straddle an epoch boundary
    _epoch_cache: list | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def batch_at(self, step: int):
        if self.sample_mode == "queue":
            return self.queue_batch_at(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, len(self.images), size=self.batch)
        return {"images": self.images[idx], "labels": self.labels[idx]}

    def _queue_perm(self, epoch: int) -> np.ndarray:
        for e, perm in self._epoch_cache or ():
            if e == epoch:
                return perm
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, epoch]))
        perm = rng.permutation(len(self.images))
        self._epoch_cache = ([(epoch, perm)]
                             + list(self._epoch_cache or ()))[:2]
        return perm

    def queue_batch_at(self, step: int):
        """Paper worker semantics as a pure function of `step`: the shared
        queue is the infinite concatenation of per-epoch permutations, and
        the step-t batch is its contiguous chunk [t*B, (t+1)*B).  When B
        does not divide the dataset length a batch simply straddles the
        epoch boundary — the workers take the next epoch's first images, so
        EVERY epoch still covers every sample exactly once (no tail dropped,
        no wraparound duplicates; tests/test_pipeline_sharding.py).  When B
        divides the length this is bit-identical to the per-epoch slicing
        it replaces."""
        n = len(self.images)
        epoch, off = divmod(step * self.batch, n)
        chunks, need = [], self.batch
        while need > 0:
            perm = self._queue_perm(epoch)
            take = min(need, n - off)
            chunks.append(perm[off:off + take])
            need -= take
            epoch, off = epoch + 1, 0
        idx = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        return {"images": self.images[idx], "labels": self.labels[idx]}

    def superstep_at(self, step: int, k: int):
        """Stacked (k, B, H, W, C) batch covering steps [step, step + k)."""
        return _stack_batches([self.batch_at(step + i) for i in range(k)])

    def worker_superstep_at(self, step: int, k: int, n_workers: int,
                            worker: int):
        """Worker ``worker``'s (k, B/N, H, W, C) shard of
        ``superstep_at(step, k)``.  In queue mode this is exactly the
        paper's shared-queue assignment: the step-t batch is the contiguous
        queue chunk queue[tB:(t+1)B], and worker w takes the next B/N
        images off it — no static split, and concat over workers
        reconstructs the global batch bit-for-bit."""
        return worker_slice(self.superstep_at(step, k), self.batch,
                            n_workers, worker)

    def worker_batches(self, step: int, n_workers: int, per_worker: int):
        """Paper-style shared queue: worker w takes samples
        queue[w::n_workers] — workers that finish early simply take the
        next image; no static split (straggler-friendly)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        order = rng.permutation(len(self.images))
        need = n_workers * per_worker
        order = np.resize(order, need)
        idx = order.reshape(per_worker, n_workers).T  # w-th row: its picks
        return {"images": self.images[idx], "labels": self.labels[idx]}

    def epochs(self, n_epochs: int, n_workers: int):
        per_worker = len(self.images) // n_workers
        for ep in range(n_epochs):
            yield self.worker_batches(ep, n_workers, per_worker)
