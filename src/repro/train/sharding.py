"""Logical-axis sharding context.

Models call ``constrain(x, "dp", "sp", None, ...)`` with *logical* axes; this
module maps them to mesh axes (or no-ops when no mesh is active, e.g. CPU
smoke tests).  ``param_shardings`` maps a SpecFactory tree of logical
PartitionSpecs to concrete NamedShardings.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "dp": ("pod", "data"),   # batch
    "fsdp": "data",          # param contraction dims (ZeRO-3)
    "tp": "model",           # tensor parallel
    "ep": "model",           # expert parallel
    "sp": "model",           # sequence parallel (activations)
    "dpsp": ("pod", "data", "model"),  # fully flattened (MoE token groups)
}

_ctx = threading.local()


def _state():
    if not hasattr(_ctx, "mesh"):
        _ctx.mesh = None
        _ctx.rules = DEFAULT_RULES
    return _ctx


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    st = _state()
    prev = (st.mesh, st.rules)
    st.mesh = mesh
    st.rules = dict(rules or DEFAULT_RULES)
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _state().mesh


def _map_axis(logical, mesh, rules):
    if logical is None:
        return None
    m = rules.get(logical, None)
    if m is None:
        return None
    if isinstance(m, str):
        return m if m in mesh.axis_names else None
    got = tuple(a for a in m if a in mesh.axis_names)
    return got if got else None


def logical_to_spec(spec: P, mesh: Mesh, rules=None) -> P:
    rules = rules or _state().rules
    return P(*[_map_axis(a, mesh, rules) for a in spec])


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, *logical_axes):
    """Sharding constraint by logical axes; axes that do not divide the
    corresponding dim are dropped (e.g. seq-parallel on a length-1 decode
    step, or whisper's 1500-frame encoder under a 16-way model axis)."""
    st = _state()
    if st.mesh is None:
        return x
    mapped = [_map_axis(a, st.mesh, st.rules) for a in logical_axes]
    mapped = [m if (m is None or x.shape[i] % _axis_size(st.mesh, m) == 0)
              else None
              for i, m in enumerate(mapped)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(st.mesh, P(*mapped)))


def param_shardings(spec_tree, mesh: Mesh, rules=None):
    """Map a tree of logical PartitionSpecs to NamedShardings."""
    def one(spec):
        return NamedSharding(mesh, logical_to_spec(spec, mesh, rules))
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def shardings_for(spec_tree, abstract_tree, mesh: Mesh, rules=None):
    """Like param_shardings, but shape-aware: mesh axes that do not divide
    the corresponding dimension are dropped (e.g. batch=1 long-context
    decode cannot shard its batch dim over `data`)."""
    rules = rules or DEFAULT_RULES
    spec_leaves = jax.tree.flatten(
        spec_tree, is_leaf=lambda s: isinstance(s, P))[0]
    abs_leaves, treedef = jax.tree.flatten(abstract_tree)
    out = []
    for spec, ab in zip(spec_leaves, abs_leaves):
        mapped = [m for m in logical_to_spec(spec, mesh, rules)]
        fixed = []
        for i, m in enumerate(mapped):
            if m is not None and (i >= len(ab.shape)
                                  or ab.shape[i] % _axis_size(mesh, m) != 0):
                m = None
            fixed.append(m)
        out.append(NamedSharding(mesh, P(*fixed)))
    return treedef.unflatten(out)


def batch_sharding(mesh: Mesh, rules=None):
    rules = rules or DEFAULT_RULES
    ax = _map_axis("dp", mesh, rules)
    return NamedSharding(mesh, P(ax))
