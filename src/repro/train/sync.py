"""Pluggable synchronization-strategy engine (DESIGN.md §5, §6).

Every gradient-synchronization mode — how workers' gradients are combined,
when parameter updates happen relative to backprop, what extra state rides
the superstep scan carry, and how that state is laid out over the worker
mesh — is one ``SyncStrategy`` subclass registered here by name.  The step
builders in ``train/step.py`` and the driver in ``launch/train.py`` are
strategy-agnostic: they build a ``StepContext`` describing the execution
path (single-instance pjit vs explicit worker mesh) and delegate the whole
step body to the strategy.  There are NO per-mode branches outside this
module.

Protocol (one strategy instance per ``SyncConfig``):

``init_state(params)``      sync buffers carried in ``TrainState["sync"]``
``state_specs(pspecs)``     logical PartitionSpecs matching ``init_state``
``stacked_state``           worker-mesh layout: ``False`` = workers provably
                            identical, state mesh-replicated (worker-count-
                            invariant checkpoints); ``True`` = per-worker
                            state with a leading ``(N, ...)`` axis
``worker_sync_layout()``    per-top-level-sync-key worker-mesh layout:
                            ``"worker"`` (leading (N, ...) axis),
                            ``"shard"`` (leading (logical_shards, ...) axis
                            — worker-count-invariant; the compression
                            residual), or ``"replicated"``
``shard_view(worker)``      the shard_map PartitionSpec implied by the above
``checkpoint_layout()``     human-readable layout contract for tooling
``resize_state(sync_state, old_worker, new_worker)``  re-slot the sync
                            state across an elastic membership change
                            N -> N' at a superstep boundary (DESIGN.md
                            §7): replicated and shard-stacked keys pass
                            through unchanged (``logical_shards`` is the
                            resize invariant), worker-stacked keys are
                            re-slotted by ``reslot_stacked``'s documented
                            shrink/grow rule
``combine_grads`` is supplied BY the execution path via ``StepContext``
                            (identity under implicit SPMD, the fixed-shape
                            gathered shard mean on the worker mesh)
``step(ctx, state, batch)`` the full train-step body (apply_update included)
``boundary(ctx, params, sync_state, step) -> (params, sync_state)``
                            end-of-step parameter hook (localsgd's K-step
                            average / τ-ring stale correction; identity
                            elsewhere)
``finish_step(ctx, state, new_params, new_opt, new_sync, losses, metrics)``
                            packs the step result: metric reduction
                            (``workers_identical`` strategies reduce with
                            the same fixed-shape mean as the gradients so
                            logged losses are worker-count-invariant;
                            diverging strategies local-mean + pmean) and
                            TrainState assembly.  Step builders that
                            compose their own step bodies (the worker-mesh
                            layerwise bucket walk) end with this hook.
``bucket_exchange(ctx, sync_state, step)``  the per-bucket exchange hook
                            for the layerwise (non-instant per-bucket
                            updates during backprop) path: returns
                            ``(exchange_bucket, finish)`` where
                            ``exchange_bucket(bucket, grads_b)`` — called
                            in reverse-production order the moment bucket
                            b's gradient exists — returns the gradient
                            bucket the optimizer should apply, and
                            ``finish(grads)`` returns the new sync state.
                            Compression slices its error-feedback residual
                            per bucket; chaos reads/writes its ring per
                            bucket; on the worker mesh every bucket runs
                            its OWN ``gathered_shard_mean`` (finer
                            comm/compute overlap than one stacked
                            reduction).

Registered strategies:

``bsp``       paper strategy B: combined fresh gradients gate every update.
``chaos``     staleness-τ controlled Hogwild (``SyncConfig.staleness``):
              * τ=0 resolves to THE ``bsp`` strategy object itself —
                bit-exactness to bsp is by construction, not by test luck;
              * worker mesh, τ>=1: each worker applies its own gradient
                contribution instantly and peers' contributions τ steps
                late (ring buffer of remote terms; workers genuinely
                diverge — the paper's arbitrary-order weight updates);
              * pjit path, τ>=1: the whole globally-reduced gradient is
                applied τ steps late (the reduction gates only the step
                output, overlapping with compute); τ=1 reproduces the
                historical staleness-1 exchange unchanged.
``localsgd``  paper strategy-C flavour: purely local updates, parameters
              averaged over workers every ``local_steps`` steps.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.chaos import (SyncConfig, compress_grads, delay_gate,
                              delay_start, localsgd_average, tree_bytes,
                              zeros_like_f32)

STRATEGIES: dict[str, type] = {}


def register(cls):
    STRATEGIES[cls.name] = cls
    return cls


def sync_modes() -> list[str]:
    """Registered mode names (drives the CLI choices in launch/train.py)."""
    return sorted(STRATEGIES)


def get_strategy(sync: SyncConfig) -> "SyncStrategy":
    try:
        cls = STRATEGIES[sync.mode]
    except KeyError:
        raise ValueError(
            f"unknown sync mode {sync.mode!r}; registered strategies: "
            f"{', '.join(sync_modes())}") from None
    return cls(sync).resolve()


def _identity(tree):
    return tree


# ---------------------------------------------------------------------------
# elastic re-slot rule (DESIGN.md §7): how a worker-stacked (N, ...) leaf
# maps onto N' slots when the worker mesh resizes at a superstep boundary.
#   N' == N                pass through (bit-exact)
#   N  == g·N' (shrink)    new worker j <- MEAN of old workers
#                          [j·g, (j+1)·g)  — the same operation localsgd's
#                          boundary applies anyway, and it collapses chaos'
#                          O(lr·τ) transient divergence onto the group mean
#   N' == g·N  (grow)      new workers [j·g, (j+1)·g) <- COPY of old worker
#                          j (each old worker seeds g fresh slots)
#   otherwise              every new worker <- the global mean over all old
#                          workers (the fully collapsed fallback)
# Means accumulate in f32 and cast back to the leaf dtype, mirroring
# ``gathered_shard_mean``'s convention.  Replicated state never passes
# through here (bsp / chaos τ=0 resizes are bit-exact by construction);
# for stacked strategies the result is defined-but-different — pinned by
# tests/test_elastic_resize.py.
# ---------------------------------------------------------------------------
def reslot_stacked(x, n_old: int, n_new: int):
    x = jnp.asarray(x)
    if x.ndim < 1 or x.shape[0] != n_old:
        raise ValueError(
            f"reslot_stacked expects a leading ({n_old}, ...) worker axis, "
            f"got shape {tuple(x.shape)}")
    if n_new == n_old:
        return x
    if n_old % n_new == 0:
        g = n_old // n_new
        grouped = x.reshape((n_new, g) + x.shape[1:])
        return jnp.mean(grouped.astype(jnp.float32), axis=1).astype(x.dtype)
    if n_new % n_old == 0:
        return jnp.repeat(x, n_new // n_old, axis=0)
    m = jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype)
    return jnp.broadcast_to(m[None], (n_new,) + x.shape[1:])


@dataclasses.dataclass(frozen=True)
class StepContext:
    """Execution-path plumbing handed to a strategy.

    The SAME strategy classes serve both the single-instance pjit path and
    the explicit worker-mesh path; what differs is how gradients are
    produced and reduced, and that difference lives here:

    ``grad_fn(params, batch) -> (losses, metrics, grads)`` — pjit path:
      scalar loss + one gradient tree; worker path: ``(s_local, ...)``
      stacks of per-micro-shard losses/metrics/gradients.
    ``combine``     local grads -> the GLOBAL mean over all shards/workers
                    (identity under implicit SPMD; the worker-count-
                    invariant gathered shard mean on the worker mesh).
    ``local_mean``  local grads -> the mean over THIS worker's data only.
    ``local_frac``  local grads -> this worker's additive term of the
                    global mean (local shard sum / total shard count).
    """
    optimizer: object
    grad_fn: Optional[Callable] = None
    combine: Callable = _identity
    local_mean: Callable = _identity
    local_frac: Callable = _identity
    explicit_workers: bool = False
    axis: Optional[str] = None
    n_workers: int = 1


# ---------------------------------------------------------------------------
# staleness ring buffer: τ params-shaped trees {"h0".."h{τ-1}"}; the slot
# for step t holds the exchange produced at t, read back at t + τ (slot
# index t % τ).  Slots are whole params-shaped trees selected with
# whole-leaf jnp.where — NOT one (τ, ...)-stacked leaf with dynamic
# gather/scatter, which changes XLA:CPU's fusion of the surrounding
# gradient computation between scan trip counts and breaks the
# K-grouping bit-exactness contract by 1 ulp (tests/test_sync_strategies
# pins scan-vs-individual bit-exactness for τ ∈ {2, 4}).  τ=1 degenerates
# to exactly the historical single prev-grad buffer.  ``dtype`` overrides
# the slot dtype (``SyncConfig.ring_dtype``: a bf16 ring halves the
# τ × params ring memory; writes quantise, reads upcast).
# ---------------------------------------------------------------------------
def init_ring(params, tau: int, dtype=None) -> dict:
    return {f"h{i}": jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params)
        for i in range(tau)}


def ring_read(hist, step, tau: int):
    idx = step % tau
    out = hist["h0"]
    for i in range(1, tau):
        out = jax.tree.map(lambda a, b, i=i: jnp.where(idx == i, b, a),
                           out, hist[f"h{i}"])
    return out


def ring_write(hist, step, tau: int, val):
    if tau == 1:  # the single slot is always overwritten — no select, so
        # τ=1 compiles to exactly the historical prev-grad graph
        return {"h0": jax.tree.map(lambda h, v: v.astype(h.dtype),
                                   hist["h0"], val)}
    idx = step % tau
    return {f"h{i}": jax.tree.map(
        lambda h, v, i=i: jnp.where(idx == i, v.astype(h.dtype), h),
        hist[f"h{i}"], val) for i in range(tau)}


@register
class BspStrategy:
    """Bulk-synchronous (paper strategy B): the combined fresh gradient is
    on the critical path of every update; workers stay provably identical,
    so worker-mesh state is replicated and checkpoints are worker-count-
    invariant."""

    name = "bsp"
    stacked_state = False     # worker mesh: state replicated
    workers_identical = True  # metrics reduce with the same fixed-shape mean
    #: whether the per-bucket exchange runs a mesh collective (drives the
    #: interleaved schedule's per-bucket delay injection — localsgd's
    #: exchange is purely local, so it must not be charged gather latency)
    bucket_exchange_gathers = True

    def __init__(self, sync: SyncConfig):
        self.sync = sync

    def resolve(self) -> "SyncStrategy":
        return self

    # -- state ---------------------------------------------------------
    def init_state(self, params) -> dict:
        if self.sync.compress:
            return {"residual": zeros_like_f32(params)}
        return {}

    def state_specs(self, pspecs) -> dict:
        if self.sync.compress:
            return {"residual": pspecs}
        return {}

    def worker_sync_layout(self) -> dict:
        """Worker-mesh layout per top-level sync-state key.  The
        compression residual is SHARD-stacked (leading (logical_shards, ...)
        axis, each worker holding its contiguous slice): quantisation error
        is carried per micro-shard, so the whole compressed exchange — and
        its checkpointed residual — is bit-identical for every worker count
        dividing logical_shards, exactly like the gradients themselves."""
        return {"residual": "shard"} if self.sync.compress else {}

    def shard_view(self, worker) -> P:
        return P(worker.axis) if self.stacked_state else P()

    def checkpoint_layout(self) -> str:
        return ("worker-stacked (leading (N, ...) axis; checkpoints pin "
                "the worker count)" if self.stacked_state else
                "replicated (worker-count-invariant checkpoints)")

    def resize_state(self, sync_state, old_worker, new_worker) -> dict:
        """Re-slot this strategy's sync state across an elastic membership
        change N -> N' (DESIGN.md §7).  The rule is driven entirely by
        ``worker_sync_layout()``: "worker" keys (chaos' staleness ring,
        localsgd has none beyond params/opt) re-slot their leading (N, ...)
        axis via ``reslot_stacked``; "shard" keys (the compression
        residual, stacked over ``logical_shards``) and replicated keys pass
        through unchanged — ``logical_shards`` is the resize invariant, so
        shard-stacked state stays bit-exact across any N -> N'."""
        if new_worker.logical_shards != old_worker.logical_shards:
            raise ValueError(
                "elastic resize must keep logical_shards fixed (it is the "
                f"bit-exactness anchor), got {old_worker.logical_shards} -> "
                f"{new_worker.logical_shards}")
        layout = self.worker_sync_layout()
        return {k: (jax.tree.map(
                        lambda x: reslot_stacked(x, old_worker.workers,
                                                 new_worker.workers), v)
                    if layout.get(k) == "worker" else v)
                for k, v in sync_state.items()}

    # -- shared pieces --------------------------------------------------
    def _maybe_compress(self, ctx: StepContext, grads, sync_state):
        """bf16-quantise the exchanged gradients with error feedback.  On
        the worker mesh the quantised values stay bf16 so the all_gather
        moves half the bytes (``gathered_shard_mean`` upcasts before its
        fixed-shape sum); on the pjit path they are upcast immediately —
        the collective is implicit there, and downstream arithmetic
        (optimizer pre-transforms) historically ran in f32."""
        new_sync = dict(sync_state)
        if self.sync.compress:
            grads, new_sync["residual"] = compress_grads(
                grads, sync_state["residual"])
            if not ctx.explicit_workers:
                grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return grads, new_sync

    def finish_step(self, ctx: StepContext, state, new_params, new_opt,
                new_sync, losses, metrics):
        packed = {**metrics, "loss": losses}
        if self.workers_identical:
            # same fixed-shape reduction as the gradients: the logged loss
            # is bit-identical across worker counts too
            packed = ctx.combine(packed)
        else:
            packed = ctx.local_mean(packed)
            if ctx.axis is not None and ctx.n_workers > 1:
                packed = jax.lax.pmean(packed, ctx.axis)
        new_state = {"params": new_params, "opt": new_opt, "sync": new_sync,
                     "step": state["step"] + 1}
        return new_state, packed

    def _reduce(self, ctx: StepContext, grads):
        return ctx.combine(grads)

    def _ring_dtype(self):
        return (jnp.dtype(self.sync.ring_dtype)
                if self.sync.ring_dtype else None)

    def boundary(self, ctx: StepContext, params, sync_state, step):
        """K-boundary hook, after the optimizer applied this step's update.
        Returns ``(params, sync_state)`` — strategies whose boundary carries
        state (localsgd's τ-ring of stale corrections) thread it here."""
        return params, sync_state

    # -- the step body ---------------------------------------------------
    def step(self, ctx: StepContext, state, batch):
        losses, metrics, grads = ctx.grad_fn(state["params"], batch)
        grads, new_sync = self._maybe_compress(ctx, grads, state["sync"])
        g = self._reduce(ctx, grads)
        new_params, new_opt = ctx.optimizer.apply(
            state["params"], g, state["opt"], state["step"])
        new_params, new_sync = self.boundary(ctx, new_params, new_sync,
                                             state["step"])
        return self.finish_step(ctx, state, new_params, new_opt, new_sync,
                            losses, metrics)

    # -- per-bucket exchange (the layerwise path, DESIGN.md §6) ----------
    def bucket_exchange(self, ctx: StepContext, sync_state, step):
        """Returns ``(exchange_bucket, finish)``: ``exchange_bucket(bucket,
        grads_b)`` is called in reverse-production order the moment bucket
        b's gradient exists and returns the exchanged gradient bucket the
        optimizer should apply — each bucket runs its own reduction, so on
        the worker mesh the per-bucket ``gathered_shard_mean`` collectives
        interleave with the per-bucket updates instead of gating on one
        stacked whole-tree reduction.  ``finish(grads)`` (full fresh-
        gradient tree) returns the new sync state — compression residual
        slices accumulate per bucket."""
        residual_out: dict = {}

        def exchange_bucket(bucket, g_b):
            g_b = self._compress_bucket(ctx, bucket, g_b, sync_state,
                                        residual_out)
            return self._reduce(ctx, g_b)

        def finish(grads):
            del grads
            return self._merge_residual(sync_state, residual_out)

        return exchange_bucket, finish

    def _compress_bucket(self, ctx: StepContext, bucket, g_b, sync_state,
                         residual_out):
        if not self.sync.compress:
            return g_b
        res_b = bucket.view(sync_state["residual"])
        g_b, new_res = compress_grads(g_b, res_b)
        residual_out.update(new_res)
        if not ctx.explicit_workers:
            g_b = jax.tree.map(lambda g: g.astype(jnp.float32), g_b)
        return g_b

    def _merge_residual(self, sync_state, residual_out):
        new_sync = dict(sync_state)
        if residual_out:
            new_sync["residual"] = {**sync_state["residual"], **residual_out}
        return new_sync


@register
class LocalSGDStrategy(BspStrategy):
    """Paper strategy-C flavour: purely local gradients; parameters averaged
    over the worker axis every ``local_steps`` steps (workers diverge
    between boundaries, so worker-mesh state is per-worker stacked).

    τ-ring boundary (DESIGN.md §8): here ``SyncConfig.staleness`` counts
    *boundaries*, not steps.  τ=0 is the blocking K-boundary average —
    the historical ``localsgd_average`` code path verbatim, so it is
    bit-exact to the pre-ring implementation by construction (no ring
    state exists at τ=0; checkpoints are unchanged).  τ>=1 replaces the
    blocking pmean with a τ-deep ring of stale *corrections*: at boundary
    m each replica computes ``pmean(params) - params``, writes it into
    ring slot m % τ, and applies the correction written at boundary m-τ
    (zero for the first τ boundaries).  The pmean therefore gates only
    the ring write — a step OUTPUT — never the boundary's own parameter
    update, so the collective overlaps with the next K·τ local steps.
    Corrections sum to zero across workers at write time, so the worker
    MEAN evolves exactly as if no averaging happened — τ-staleness only
    perturbs each replica's pull toward that shared mean trajectory.

    With delay injection (``collective_delay_ns_per_byte`` > 0) a
    per-slot deadline token rides the sync state: the all-reduce's
    2×param-bytes charge is stamped at boundary m and slept off when the
    slot is read back at boundary m+τ — after K·τ local steps of compute
    the remainder is ~0, which is the measurable overlap win
    (benchmarks/overlap.py) vs τ=0's full synchronous charge."""

    name = "localsgd"
    stacked_state = True
    workers_identical = False
    bucket_exchange_gathers = False  # per-bucket reduce is purely local

    def _tau(self) -> int:
        return self.sync.staleness

    def _has_tokens(self) -> bool:
        return (self._tau() >= 1
                and self.sync.collective_delay_ns_per_byte > 0)

    def init_state(self, params) -> dict:
        st = super().init_state(params)
        if self._tau() >= 1:
            st["lsring"] = init_ring(params, self._tau(), self._ring_dtype())
            if self._has_tokens():
                # zero deadlines are already in the past -> first reads
                # sleep nothing (matches the zero corrections they gate)
                st["lstok"] = jnp.zeros((self._tau(),), jnp.float32)
        return st

    def state_specs(self, pspecs) -> dict:
        st = super().state_specs(pspecs)
        if self._tau() >= 1:
            st["lsring"] = {f"h{i}": pspecs for i in range(self._tau())}
            if self._has_tokens():
                st["lstok"] = P()
        return st

    def worker_sync_layout(self) -> dict:
        layout = super().worker_sync_layout()
        if self._tau() >= 1:
            layout["lsring"] = "worker"
            if self._has_tokens():
                layout["lstok"] = "worker"
        return layout

    def _reduce(self, ctx: StepContext, grads):
        return ctx.local_mean(grads)

    def boundary(self, ctx: StepContext, params, sync_state, step):
        sync = self.sync
        tau = self._tau()
        delay = sync.collective_delay_ns_per_byte
        if tau == 0:
            return (localsgd_average(sync, params, step,
                                     delay_ns_per_byte=delay), sync_state)
        do_avg = ((step + 1) % sync.local_steps) == 0
        # 0-based boundary index; only meaningful when do_avg (clamped so
        # the ring arithmetic stays valid off-boundary, where every write
        # and apply is select-disabled anyway)
        m = jnp.maximum((step + 1) // sync.local_steps - 1, 0)
        ring = sync_state["lsring"]
        new_sync = dict(sync_state)
        gated = "lstok" in sync_state and sync.axis_name is not None
        stale = ring_read(ring, m, tau)
        if gated:
            # sleep whatever remains of the deadline stamped τ boundaries
            # ago — K·τ local steps of compute have already eaten into it
            stale = delay_gate(stale, sync_state["lstok"][m % tau], params)
        new_params = jax.tree.map(
            lambda p, s: jnp.where(do_avg, p + s.astype(p.dtype), p),
            params, stale)
        if sync.axis_name is not None:
            avg = jax.tree.map(
                lambda p: jax.lax.pmean(p, sync.axis_name), new_params)
        else:
            avg = new_params  # single instance: correction is exactly zero
        corr = jax.tree.map(lambda a, p: a - p, avg, new_params)
        written = ring_write(ring, m, tau, corr)
        new_sync["lsring"] = jax.tree.map(
            lambda w, h: jnp.where(do_avg, w, h), written, ring)
        if gated:
            ms = 2.0 * tree_bytes(params) * delay * 1e-6  # all-reduce: 2×
            tok = delay_start(corr, jnp.where(do_avg, ms, 0.0))
            new_sync["lstok"] = sync_state["lstok"].at[m % tau].set(
                jnp.where(do_avg, tok, sync_state["lstok"][m % tau]))
        return new_params, new_sync


@register
class ChaosStrategy(BspStrategy):
    """Staleness-τ controlled Hogwild (the paper's CHAOS proper).

    τ = ``SyncConfig.staleness``.  τ=0 never reaches this class —
    ``resolve()`` hands back a ``BspStrategy``, so chaos(τ=0) IS bsp (state
    layout, checkpoints, and arithmetic identical by construction).

    τ>=1, worker mesh (``ctx.explicit_workers``): each worker computes
    gradients at its OWN current weights and applies, in the same step, its
    own additive term of the global mean plus the τ-step-stale remote terms
    from the ring buffer — local updates are instant, peers' updates are
    non-instant and fold in without a barrier, in arbitrary order across
    workers.  Workers genuinely diverge (transiently, by O(lr·τ) per the
    delayed-SGD analysis), so state is worker-stacked.

    τ>=1, pjit path: one logical instance — "peers" are the implicit
    cross-replica reduction, so the whole combined gradient is applied τ
    steps late and the reduction gates only the step output (overlappable).
    τ=1 is the historical staleness-1 delayed exchange, bit-for-bit.
    """

    name = "chaos"
    stacked_state = True       # τ>=1 worker mesh: workers diverge
    workers_identical = False

    def resolve(self) -> "SyncStrategy":
        if self.sync.staleness == 0:
            return BspStrategy(self.sync)
        return self

    def init_state(self, params) -> dict:
        # ring slots default to param dtype: gradients are produced in
        # param dtype anyway and a τ-deep f32 copy of a large model would
        # be the dominant sync-state cost; ``ring_dtype="bfloat16"``
        # (reusing the compression cast) halves even that
        st = {"hist": init_ring(params, self.sync.staleness,
                                self._ring_dtype())}
        if self.sync.compress:
            st["residual"] = zeros_like_f32(params)
        return st

    def state_specs(self, pspecs) -> dict:
        # each ring slot is params-shaped, so it shards exactly like params
        st = {"hist": {f"h{i}": pspecs
                       for i in range(self.sync.staleness)}}
        if self.sync.compress:
            st["residual"] = pspecs
        return st

    def worker_sync_layout(self) -> dict:
        layout = {"hist": "worker"}
        if self.sync.compress:
            layout["residual"] = "shard"
        return layout

    def step(self, ctx: StepContext, state, batch):
        if ctx.explicit_workers:
            return self._hogwild_step(ctx, state, batch)
        return self._delayed_step(ctx, state, batch)

    def _delayed_step(self, ctx: StepContext, state, batch):
        """pjit path: 1) update with the τ-step-stale globally-reduced
        gradient (available immediately, no blocking collective); 2) fresh
        gradients at the new params -> ring slot t, read back at t+τ; their
        reduction gates only the step OUTPUT (overlappable)."""
        tau = self.sync.staleness
        hist = state["sync"]["hist"]
        stale = ring_read(hist, state["step"], tau)
        new_params, new_opt = ctx.optimizer.apply(
            state["params"], stale, state["opt"], state["step"])
        losses, metrics, grads = ctx.grad_fn(new_params, batch)
        grads, new_sync = self._maybe_compress(ctx, grads, state["sync"])
        new_sync["hist"] = ring_write(hist, state["step"], tau,
                                      ctx.combine(grads))
        return self.finish_step(ctx, state, new_params, new_opt, new_sync,
                            losses, metrics)

    def _hogwild_step(self, ctx: StepContext, state, batch):
        """Worker mesh: own term instant + remote terms τ steps stale.
        With compression the per-shard quantised gradients feed BOTH the
        instant own term and the gathered exchange, so the error-feedback
        residual stays worker-count-invariant (shard-stacked)."""
        tau = self.sync.staleness
        hist = state["sync"]["hist"]
        losses, metrics, grads = ctx.grad_fn(state["params"], batch)
        grads, new_sync = self._maybe_compress(ctx, grads, state["sync"])
        own = ctx.local_frac(grads)
        stale_remote = ring_read(hist, state["step"], tau)
        g = jax.tree.map(lambda o, s: o + s.astype(jnp.float32),
                         own, stale_remote)
        new_params, new_opt = ctx.optimizer.apply(
            state["params"], g, state["opt"], state["step"])
        # this step's remote term: the all_gather'd global mean minus the
        # own term — it gates only the ring write (the step output), never
        # this step's update
        remote_now = jax.tree.map(lambda a, o: a - o, ctx.combine(grads),
                                  own)
        new_sync["hist"] = ring_write(hist, state["step"], tau, remote_now)
        return self.finish_step(ctx, state, new_params, new_opt, new_sync,
                            losses, metrics)

    def bucket_exchange(self, ctx: StepContext, sync_state, step):
        """Layerwise chaos (paper §3 order): the forward pass runs at the
        pre-update weights; during backprop each bucket's update applies,
        the moment that bucket's fresh gradient exists, the τ-step-stale
        exchange — plus, on the worker mesh, the worker's own instant term
        (the hogwild decomposition, per bucket) — and the fresh exchange
        terms enter the ring for step t+τ bucket by bucket.  (The
        non-layerwise pjit chaos instead evaluates gradients at the
        post-update weights — the overlap-friendly SPMD ordering; both are
        staleness-τ members of the same family, DESIGN.md §5.)"""
        tau = self.sync.staleness
        stale = ring_read(sync_state["hist"], step, tau)
        residual_out: dict = {}
        fresh: dict = {}

        def exchange_bucket(bucket, g_b):
            g_b = self._compress_bucket(ctx, bucket, g_b, sync_state,
                                        residual_out)
            stale_b = bucket.view(stale)
            if ctx.explicit_workers:
                own = ctx.local_frac(g_b)
                fresh.update(jax.tree.map(
                    lambda a, o: a - o, ctx.combine(g_b), own))
                return jax.tree.map(
                    lambda o, s: o + s.astype(jnp.float32), own, stale_b)
            fresh.update(ctx.combine(g_b))
            return stale_b

        def finish(grads):
            del grads
            new_sync = self._merge_residual(sync_state, residual_out)
            new_sync["hist"] = ring_write(sync_state["hist"], step, tau,
                                          fresh)
            return new_sync

        return exchange_bucket, finish


SyncStrategy = BspStrategy  # protocol root: every strategy subclasses it
