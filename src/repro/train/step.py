"""Train/serve step builders: the glue between models, the SyncStrategy
engine, optimizers, and sharding.

``make_train_step(cfg, sync)``  -> (step_fn, TrainState helpers)
``make_superstep(cfg, sync)``   -> K steps per dispatch via lax.scan over a
                                   stacked (K, B, ...) batch (DESIGN.md §3)
``make_serve_step(cfg)``        -> decode step over a KV/state cache

Synchronization behaviour (bsp / chaos(τ) / localsgd / anything registered
later) is fully delegated to ``train/sync.py``: this module builds the
execution-path ``StepContext`` (how gradients are produced and reduced) and
the strategy supplies the step body — there are no per-mode branches here
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.chaos import (SyncConfig, delay_gate, delay_start,
                              delay_tie, gathered_shard_mean)
from repro.core.schedule import make_lr_fn
from repro.core.types import ArchConfig, WorkerConfig
from repro.models import layers as ML
from repro.models.api import get_ops
from repro.obs import trace as obs_trace
from repro.optim import adamw, sgd
from repro.train.sync import StepContext, get_strategy


def make_optimizer(cfg: ArchConfig, base_lr: float = 3e-4,
                   total_steps: int = 10_000, kind: str = "auto"):
    """``kind``: "auto" (family default: CNN -> the paper's plain SGD,
    everything else -> adamw), or an explicit "sgd" / "momentum" /
    "adamw" override (driver ``--optim``)."""
    lr_fn = make_lr_fn(cfg.lr_schedule,
                       base_lr=1e-3 if cfg.family == "cnn" else base_lr,
                       steps_per_epoch=max(total_steps // 70, 1),
                       total_steps=total_steps)
    if kind == "auto":
        kind = "sgd" if cfg.family == "cnn" else "adamw"
    if kind == "sgd":
        return sgd(lr_fn)  # paper: plain SGD + decay schedule
    if kind == "momentum":
        return sgd(lr_fn, momentum=0.9)
    if kind == "adamw":
        return adamw(lr_fn, moment_dtype=cfg.opt_moment_dtype)
    raise ValueError(
        f"unknown optimizer kind {kind!r}; choose auto|sgd|momentum|adamw")


def init_train_state(cfg: ArchConfig, key, sync: SyncConfig,
                     optimizer=None, abstract: bool = False):
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    strat = get_strategy(sync)
    if abstract:
        params = jax.eval_shape(ops.init, key)
    else:
        params = ops.init(key)
    opt_state = (jax.eval_shape(optimizer.init, params) if abstract
                 else optimizer.init(params))
    sync_state = (jax.eval_shape(strat.init_state, params)
                  if abstract else strat.init_state(params))
    return {"params": params, "opt": opt_state, "sync": sync_state,
            "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                     else jnp.zeros((), jnp.int32))}


def state_specs(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Logical PartitionSpec tree matching init_train_state's output."""
    ops = get_ops(cfg)
    pspecs = ops.param_specs()
    optimizer = optimizer or make_optimizer(cfg)
    strat = get_strategy(sync)

    # optimizer state mirrors param sharding (one params-shaped tree per
    # top-level key: adamw {m, v}, sgd-momentum {mu}); the sync strategy
    # owns its own state layout (chaos' ring is τ separate params-shaped
    # slot trees, each sharded exactly like params)
    abstract = jax.eval_shape(ops.init, jax.random.key(0))
    opt_abs = jax.eval_shape(optimizer.init, abstract)
    opt_specs = {k: pspecs for k in opt_abs} if isinstance(opt_abs, dict) else {}
    return {"params": pspecs, "opt": opt_specs,
            "sync": strat.state_specs(pspecs), "step": P()}


def _make_grad_fn(cfg: ArchConfig, ops):
    """(params, batch) -> (loss, metrics, grads), with optional
    microbatching (gradient accumulation): the global batch is split into
    cfg.micro_batches slices processed sequentially — activation memory
    scales 1/n_micro."""
    def grad_fn(params, batch):
        n_micro = max(cfg.micro_batches, 1)
        if n_micro == 1:
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params,
                                                                   batch)
            return l, m, g

        def split(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return (l, m), g

        from repro.models import layers as MLY
        if MLY.UNROLL_ATTN:  # dry-run: unrolled for honest cost accounting
            (l, m), g = one(jax.tree.map(lambda x: x[0], mb))
            for i in range(1, n_micro):
                (li, mi), gi = one(jax.tree.map(lambda x, i=i: x[i], mb))
                l = l + li
                m = jax.tree.map(jnp.add, m, mi)
                g = jax.tree.map(jnp.add, g, gi)
        else:
            def body(carry, b):
                l, m, g = carry
                (li, mi), gi = one(b)
                return (l + li, jax.tree.map(jnp.add, m, mi),
                        jax.tree.map(jnp.add, g, gi)), None
            (l0, m0), g0 = one(jax.tree.map(lambda x: x[0], mb))
            (l, m, g), _ = jax.lax.scan(
                body, (l0, m0, g0), jax.tree.map(lambda x: x[1:], mb))
        inv = 1.0 / n_micro
        return (l * inv, jax.tree.map(lambda t: t * inv, m),
                jax.tree.map(lambda t: t * inv, g))

    return grad_fn


def make_train_step(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns step(state, batch) -> (new_state, metrics).

    The step body comes from the registered SyncStrategy; this builder only
    supplies the single-instance StepContext (implicit-SPMD reductions are
    identities).  ``sync.layerwise`` routes through the per-layer
    non-instant-update path instead (CNN + stateless SGD, DESIGN.md §5).
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    strat = get_strategy(sync)
    if sync.layerwise:
        return _make_bucket_step(cfg, sync, strat, ops, optimizer)
    ctx = StepContext(optimizer=optimizer, grad_fn=_make_grad_fn(cfg, ops))

    def step(state, batch):
        return strat.step(ctx, state, batch)

    return step


def _apply_bucket(optimizer, bucket, params, g_b, opt_state, step):
    """One bucket's optimizer update with sliced state: returns
    ``(new_params_b, new_opt_state)`` — ``apply_raw`` is strictly per-leaf,
    so bucket-by-bucket application is bit-identical to one whole-tree
    apply given the same (pre-transformed) gradients."""
    st_b = optimizer.slice_state(opt_state, bucket.keys)
    new_p_b, new_st = optimizer.apply_raw(bucket.view(params), g_b, st_b,
                                          step)
    return new_p_b, optimizer.merge_state(opt_state, bucket.keys, new_st)


def _bucket_walk(spec, optimizer, exchange_bucket, params, opt_state, grads,
                 step):
    """Collect-then-walk flavour of the bucket tape (reverse-production
    order): exchange then update each bucket.  Used where all bucket
    gradients exist before the walk — the worker mesh (per-shard gradients
    come stacked out of ``lax.map``) and optimizers with a global
    ``pre_apply`` transform (adamw's clip needs the whole exchanged tree).
    Per-bucket exchange + update chaining is preserved either way."""
    new_params = dict(params)
    opt = opt_state
    if optimizer.pre_apply is None:
        for bucket in reversed(spec):
            g_ex = exchange_bucket(bucket, bucket.view(grads))
            new_p_b, opt = _apply_bucket(optimizer, bucket, new_params,
                                         g_ex, opt, step)
            new_params.update(new_p_b)
        return new_params, opt
    exchanged = {}
    for bucket in reversed(spec):
        exchanged.update(exchange_bucket(bucket, bucket.view(grads)))
    exchanged = optimizer.pre_apply(exchanged)
    for bucket in reversed(spec):
        new_p_b, opt = _apply_bucket(optimizer, bucket, new_params,
                                     bucket.view(exchanged), opt, step)
        new_params.update(new_p_b)
    return new_params, opt


def _make_bucket_step(cfg: ArchConfig, sync: SyncConfig, strat, ops,
                      optimizer):
    """Per-bucket non-instant updates during backprop (paper §3: dW_l is
    applied the moment layer l's gradient is produced, in reverse
    production order) — any model family via its ``bucket_spec()`` (the
    CNN's walk is chained to each layer's VJP gradient production, through
    both the XLA and Pallas-kernel paths), any optimizer via per-bucket
    state slicing, and it composes with the superstep scan unchanged.

    ``cfg.micro_batches > 1`` composes via the bucket-granular accumulator:
    per-bucket gradients accumulate across the micro-shards (the shared
    ``_make_grad_fn`` scan — bucket slices of one whole-tree accumulation),
    then every bucket exchanges ONCE per step on its accumulated mean and
    the per-bucket updates walk in the same reverse-production order.  A
    per-bucket update cannot fire mid-accumulation (later micro-shards'
    gradients would not exist yet), so the tape degrades to the
    collect-then-walk schedule — numerics identical to the batched
    micro-batch step bucket-by-bucket."""
    spec = ops.bucket_spec()
    ctx = StepContext(optimizer=optimizer)
    n_micro = max(cfg.micro_batches, 1)
    acc_grad_fn = _make_grad_fn(cfg, ops) if n_micro > 1 else None

    def step(state, batch):
        exchange_bucket, finish = strat.bucket_exchange(ctx, state["sync"],
                                                        state["step"])
        if n_micro > 1:
            loss, metrics, grads = acc_grad_fn(state["params"], batch)
            new_params, new_opt = _bucket_walk(
                spec, optimizer, exchange_bucket, state["params"],
                state["opt"], grads, state["step"])
        elif optimizer.pre_apply is None:
            # true tape: each bucket's exchange + update fires inside the
            # backward walk, the moment that bucket's gradient is produced
            opt_box = [state["opt"]]

            def on_bucket(bucket, p_b, g_b):
                del p_b  # the walk's running params are in new_params
                g_ex = exchange_bucket(bucket, g_b)
                new_p_b, opt_box[0] = _apply_bucket(
                    optimizer, bucket, state["params"], g_ex, opt_box[0],
                    state["step"])
                return new_p_b

            loss, metrics, new_params, grads = ops.loss_and_grads(
                state["params"], batch, tape=on_bucket)
            new_opt = opt_box[0]
        else:
            # globally-coupled optimizer (adamw's whole-tree clip): produce
            # the tape gradients, exchange per bucket, transform once, then
            # walk the per-bucket updates in the same reverse order
            loss, metrics, grads = ops.loss_and_grads(state["params"],
                                                      batch)
            new_params, new_opt = _bucket_walk(
                spec, optimizer, exchange_bucket, state["params"],
                state["opt"], grads, state["step"])
        new_sync = finish(grads)
        new_params, new_sync = strat.boundary(ctx, new_params, new_sync,
                                              state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "sync": new_sync, "step": state["step"] + 1}
        return new_state, {**metrics, "loss": loss}

    return step


def make_superstep(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns superstep(state, batches) -> (new_state, metrics).

    ``batches`` is a stacked (K, B, ...) pytree (``pipeline.superstep_at``);
    the K constituent steps run inside ONE compiled ``jax.lax.scan``, so the
    host dispatches (and syncs on metrics) once per K steps instead of once
    per step.  The whole TrainState — params, optimizer moments, the sync
    strategy's buffers (chaos ring / compression residual), and the step
    counter that drives the LR schedule and localsgd boundary — is the scan
    carry, so every registered strategy composes unchanged and the result
    is bit-identical to K individual dispatches (tests/test_superstep.py).
    Metrics come back stacked (K,).

    jit with ``donate_argnums=(0,)``: the TrainState is donated so a
    superstep is update-in-place at the HBM level.
    """
    step = make_train_step(cfg, sync, optimizer)

    def superstep(state, batches):
        return jax.lax.scan(step, state, batches)

    return superstep


def make_worker_train_step(cfg: ArchConfig, sync: SyncConfig,
                           worker: WorkerConfig, optimizer=None):
    """Per-worker step body for shard_map execution over the worker mesh.

    Runs on each worker's local slice of the global batch (B/N examples,
    contiguous in global batch order).  The local slice is processed as
    ``worker.shards_per_worker`` fixed-size micro-shards via ``lax.map``
    (identical per-shard shapes for every worker count), and the strategy's
    collectives thread over ``worker.axis`` through the StepContext
    reducers:

      combine     - the worker-count-invariant gathered shard mean
                    (all_gather + ONE fixed-shape sum over logical_shards)
      local_mean  - mean over this worker's own micro-shards
      local_frac  - this worker's additive term of the global mean
                    (local shard sum / logical_shards)
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    if cfg.micro_batches > 1:
        raise NotImplementedError(
            "cfg.micro_batches is not consulted on the worker-mesh path — "
            "the logical-shard decomposition IS the microbatching here "
            "(per-shard batch = B / logical_shards); raise "
            "WorkerConfig.logical_shards to shrink per-shard activation "
            "memory instead")
    if sync.axis_name != worker.axis:
        sync = dataclasses.replace(sync, axis_name=worker.axis)
    strat = get_strategy(sync)
    N, S, axis = worker.workers, worker.logical_shards, worker.axis
    s_local = worker.shards_per_worker

    def shard_grads(params, batch):
        """(losses, metrics, grads), each stacked (S/N, ...) over this
        worker's micro-shards.  Per-shard shapes are independent of N, so
        per-shard values are bit-identical for every worker count."""
        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return l, m, g
        shards = jax.tree.map(
            lambda x: x.reshape((s_local, x.shape[0] // s_local)
                                + x.shape[1:]), batch)
        return jax.lax.map(one, shards)

    # local reductions accumulate in f32 like gathered_shard_mean (identity
    # for the uncompressed f32 path; with per-shard bf16 compression the
    # stacks arrive bf16 and must not sum in bf16)
    delay = sync.collective_delay_ns_per_byte
    ctx = StepContext(
        optimizer=optimizer, grad_fn=shard_grads,
        # blocking delay injection (the synchronous-exchange model) lives
        # here, at the gather; delay == 0 leaves the graph untouched
        combine=lambda t: gathered_shard_mean(t, axis, N, S,
                                              delay_ns_per_byte=delay),
        local_mean=lambda t: jax.tree.map(
            lambda x: jnp.sum(x.astype(jnp.float32), 0) / s_local, t),
        # sum * (1/S), NOT sum / S: gathered_shard_mean multiplies by the
        # reciprocal, and the hogwild own/remote decomposition must use the
        # same arithmetic so remote_now == 0 exactly when all shards are
        # local (N=1 chaos == bsp for ANY logical_shards, not just pow2)
        local_frac=lambda t: jax.tree.map(
            lambda x: jnp.sum(x.astype(jnp.float32), 0) * (1.0 / S), t),
        explicit_workers=True, axis=axis, n_workers=N)

    if sync.layerwise:
        spec = ops.bucket_spec()
        # interleaved schedule (DESIGN.md §8): fire each bucket's exchange
        # collective the moment that layer's stacked gradient is produced
        # during backprop, via the model's shard tape.  Needs a per-leaf
        # optimizer (no whole-tree pre_apply — adamw's clip must see every
        # exchanged bucket first); otherwise, and for families without a
        # shard tape, fall back to collect-then-walk.  The tape restructures
        # the backward into per-layer map bodies, which XLA:CPU canonicalises
        # differently from the whole-chain body — gradients agree with
        # collect-then-walk only to ~1 ulp, which is why interleave is
        # opt-in and the bit-exactness pins ride the collect schedule.
        interleave = (sync.interleave and ops.shard_bucket_grads is not None
                      and optimizer.pre_apply is None)
        if interleave:
            # the interleaved walk places its own start/gate delay pairs, so
            # its combine must not also blocking-inject
            ctx_i = dataclasses.replace(
                ctx, combine=lambda t: gathered_shard_mean(t, axis, N, S))
            # static per-bucket gather cost: result bytes = logical_shards ×
            # per-shard gradient bytes (bf16 on the compressed wire)
            itemsize = 2 if sync.compress else 4
            abstract = ops.abstract_params()
            bucket_bytes = {
                b.name: S * sum(l.size * itemsize for l in
                                jax.tree.leaves(b.view(abstract)))
                for b in spec}
            bucket_ms = {name: nbytes * delay * 1e-6
                         for name, nbytes in bucket_bytes.items()}
            inject = delay > 0 and N > 1 and strat.bucket_exchange_gathers
            # per-bucket exchange stamps (obs, DESIGN.md §11): when a tracer
            # is installed AT BUILD TIME, the issue/gate pair is routed
            # through it — the tracer's callbacks stamp event times AND
            # carry the same deadline token, so tracing + injection share
            # one callback pair (never double-charged).  No tracer ⇒ this
            # whole branch compiles exactly as before.
            tracer = obs_trace.get_tracer()
            stamp = (tracer is not None and N > 1
                     and strat.bucket_exchange_gathers)

            def bucket_step(state, batch):
                exchange_bucket, finish = strat.bucket_exchange(
                    ctx_i, state["sync"], state["step"])
                shards = jax.tree.map(
                    lambda x: x.reshape((s_local, x.shape[0] // s_local)
                                        + x.shape[1:]), batch)
                widx = jax.lax.axis_index(axis) if stamp else None
                exchanged = {}

                def on_bucket(bucket, g_b):
                    g_ex = exchange_bucket(bucket, g_b)
                    # deadline stamped when this bucket's gradient exists =
                    # the collective's issue point, mid-backward
                    if stamp:
                        tok = tracer.bucket_issue(
                            g_b, bucket.name,
                            delay_ms=bucket_ms[bucket.name] if inject
                            else 0.0,
                            worker=widx,
                            args={"bytes": bucket_bytes[bucket.name],
                                  "tau": sync.staleness,
                                  "schedule": "interleave"})
                    elif inject:
                        tok = delay_start(g_b, bucket_ms[bucket.name])
                    else:
                        tok = None
                    exchanged[bucket.name] = (g_ex, tok)
                    return tok

                losses, metrics, grads = ops.shard_bucket_grads(
                    state["params"], shards, on_bucket)
                # gates anchor on the LAST-produced gradient: each bucket
                # sleeps only what remains of its deadline after the rest
                # of the backward walk ran — latency hidden behind compute
                anchor = grads[spec[0].name]
                new_params = dict(state["params"])
                new_opt = state["opt"]
                for bucket in reversed(spec):
                    g_ex, tok = exchanged[bucket.name]
                    if tok is not None and stamp:
                        g_ex = tracer.bucket_gate(g_ex, tok, anchor,
                                                  bucket.name, worker=widx)
                    elif tok is not None:
                        g_ex = delay_gate(g_ex, tok, anchor)
                    new_p_b, new_opt = _apply_bucket(
                        optimizer, bucket, new_params, g_ex, new_opt,
                        state["step"])
                    new_params.update(new_p_b)
                new_sync = finish(grads)
                new_params, new_sync = strat.boundary(
                    ctx_i, new_params, new_sync, state["step"])
                return strat.finish_step(ctx_i, state, new_params, new_opt,
                                         new_sync, losses, metrics)

            return bucket_step

        # collect-then-walk: gradients come stacked out of the per-shard
        # lax.map, then every bucket runs its own gathered_shard_mean +
        # update in reverse-production order — finer comm/compute
        # interleave than one stacked whole-tree reduction, same per-leaf
        # arithmetic (bit-exact to the batched update for bsp, any N
        # dividing logical_shards); with delay injection each bucket's
        # gather charge lands synchronously inside the walk (the baseline
        # benchmarks/overlap.py measures the interleaved tape against)
        tracer = obs_trace.get_tracer()
        stamp = (tracer is not None and N > 1
                 and strat.bucket_exchange_gathers)
        if stamp:
            itemsize = 2 if sync.compress else 4
            abstract = ops.abstract_params()
            bucket_bytes = {
                b.name: S * sum(l.size * itemsize for l in
                                jax.tree.leaves(b.view(abstract)))
                for b in spec}

        def bucket_step(state, batch):
            exchange_bucket, finish = strat.bucket_exchange(
                ctx, state["sync"], state["step"])
            if stamp:
                # wrap each bucket's exchange in an issue/gate stamp pair:
                # the span covers the gather (and, with --collective-delay,
                # the blocking charge gathered_shard_mean injects inside it)
                widx = jax.lax.axis_index(axis)
                inner_exchange = exchange_bucket

                def exchange_bucket(bucket, g_b):
                    tok = tracer.bucket_issue(
                        g_b, bucket.name, worker=widx,
                        args={"bytes": bucket_bytes[bucket.name],
                              "tau": sync.staleness,
                              "schedule": "collect"})
                    g_ex = inner_exchange(bucket, delay_tie(g_b, tok))
                    return tracer.bucket_gate(g_ex, tok, g_ex, bucket.name,
                                              worker=widx)
            losses, metrics, grads = ctx.grad_fn(state["params"], batch)
            new_params, new_opt = _bucket_walk(
                spec, optimizer, exchange_bucket, state["params"],
                state["opt"], grads, state["step"])
            new_sync = finish(grads)
            new_params, new_sync = strat.boundary(ctx, new_params, new_sync,
                                                  state["step"])
            return strat.finish_step(ctx, state, new_params, new_opt, new_sync,
                                 losses, metrics)

        return bucket_step

    def step(state, batch):
        return strat.step(ctx, state, batch)

    return step


def init_worker_state(cfg: ArchConfig, key, sync: SyncConfig,
                      worker: WorkerConfig, optimizer=None):
    """TrainState for the worker-mesh route.  Strategies whose workers stay
    provably identical (bsp, chaos τ=0) keep UNSTACKED (mesh-replicated)
    state — byte-for-byte the same checkpoint layout as a single-device
    run, which is what makes those checkpoints worker-count-invariant.
    Strategies whose workers genuinely diverge (localsgd, chaos τ>=1)
    carry a leading (N, ...) worker axis.  Sync-state keys follow the
    strategy's ``worker_sync_layout()``: "worker" leaves get the (N, ...)
    axis, "shard" leaves (the compression residual) a (logical_shards, ...)
    axis — worker-count-invariant like the gradients they correct."""
    from repro.core.chaos import replicate_for_workers

    strat = get_strategy(sync)
    state = init_train_state(cfg, key, sync, optimizer)
    layout = strat.worker_sync_layout()
    sync_state = {
        k: (replicate_for_workers(v, worker.workers)
            if layout.get(k) == "worker"
            else replicate_for_workers(v, worker.logical_shards)
            if layout.get(k) == "shard" else v)
        for k, v in state["sync"].items()}
    if strat.stacked_state:
        state = {k: replicate_for_workers(v, worker.workers)
                 for k, v in state.items() if k != "sync"}
    else:
        state = {k: v for k, v in state.items() if k != "sync"}
    state["sync"] = sync_state
    return state


def resize_worker_state(state, sync: SyncConfig, old_worker: WorkerConfig,
                        new_worker: WorkerConfig):
    """Re-slot a worker-route TrainState across an elastic membership
    change N -> N' at a superstep boundary (DESIGN.md §7), WITHOUT going
    through a checkpoint.

    Strategies with replicated state (bsp, chaos τ=0) pass through
    untouched — the resize is bit-exact because the state never depended on
    the worker count in the first place.  Stacked strategies (localsgd,
    chaos τ>=1) re-slot every (N, ...) leaf — params, optimizer moments,
    the step counter, and the sync state's "worker"-layout keys — via
    ``train/sync.py::reslot_stacked``'s documented shrink/grow rule;
    "shard"-layout sync keys (the compression residual) ride through
    unchanged because ``logical_shards`` is the resize invariant."""
    from repro.train.sync import reslot_stacked

    strat = get_strategy(sync)
    state = dict(state)
    sync_state = state.pop("sync")
    if strat.stacked_state:
        state = {k: jax.tree.map(
                     lambda x: reslot_stacked(x, old_worker.workers,
                                              new_worker.workers), v)
                 for k, v in state.items()}
    state["sync"] = strat.resize_state(sync_state, old_worker, new_worker)
    return state


def make_worker_superstep(cfg: ArchConfig, sync: SyncConfig,
                          worker: WorkerConfig, mesh, optimizer=None):
    """Superstep over the worker mesh: the K-step ``lax.scan`` runs INSIDE
    ``shard_map`` over ``mesh``'s 1-D worker axis, so per-step collectives
    (gradient exchange / boundary averages) stay on-device across all K
    steps and the host still dispatches once per superstep.

    Call with the GLOBAL stacked (K, B, ...) batch; shard_map splits axis 1
    over workers (worker w's slice == ``pipeline.worker_superstep_at(step,
    k, N, w)``).  State specs follow ``init_worker_state``'s layout — the
    strategy's ``shard_view`` (replicated or worker-stacked).  Metrics are
    replicated (K,) vectors.  jit'd with the TrainState donated.
    """
    from jax.experimental.shard_map import shard_map

    step = make_worker_train_step(cfg, sync, worker, optimizer)
    strat = get_strategy(sync)
    stacked = strat.stacked_state
    layout = strat.worker_sync_layout()

    def _map_sync(sync_state, fn):
        # "worker" keys squeeze/restack their leading worker axis at the
        # shard_map boundary; "shard" keys (the per-micro-shard compression
        # residual) arrive as this worker's (s_local, ...) slice and pass
        # through — the per-shard stacking IS the in-step layout
        return {k: (jax.tree.map(fn, v) if layout.get(k) == "worker" else v)
                for k, v in sync_state.items()}

    def superstep(state, batches):
        state = dict(state)
        sync_state = state.pop("sync")
        if stacked:
            state = jax.tree.map(lambda x: x[0], state)
        state["sync"] = _map_sync(sync_state, lambda x: x[0])
        state, metrics = jax.lax.scan(step, state, batches)
        state = dict(state)
        sync_state = state.pop("sync")
        if stacked:
            state = jax.tree.map(lambda x: x[None], state)
        state["sync"] = _map_sync(sync_state, lambda x: x[None])
        return state, metrics

    base = strat.shard_view(worker)
    sync_spec = {k: (P() if v == "replicated" else P(worker.axis))
                 for k, v in layout.items()}
    state_spec = {"params": base, "opt": base, "step": base,
                  "sync": sync_spec}
    fn = shard_map(superstep, mesh=mesh,
                   in_specs=(state_spec, P(None, worker.axis)),
                   out_specs=(state_spec, P()),
                   check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))


def make_serve_step(cfg: ArchConfig):
    ops = get_ops(cfg)

    def serve_step(params, cache, tokens, cache_len):
        logits, new_cache = ops.decode(params, cache, tokens, cache_len)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), new_cache
    return serve_step
