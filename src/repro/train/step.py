"""Train/serve step builders: the glue between models, the SyncStrategy
engine, optimizers, and sharding.

``make_train_step(cfg, sync)``  -> (step_fn, TrainState helpers)
``make_superstep(cfg, sync)``   -> K steps per dispatch via lax.scan over a
                                   stacked (K, B, ...) batch (DESIGN.md §3)
``make_serve_step(cfg)``        -> decode step over a KV/state cache

Synchronization behaviour (bsp / chaos(τ) / localsgd / anything registered
later) is fully delegated to ``train/sync.py``: this module builds the
execution-path ``StepContext`` (how gradients are produced and reduced) and
the strategy supplies the step body — there are no per-mode branches here
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.chaos import SyncConfig, gathered_shard_mean
from repro.core.schedule import make_lr_fn
from repro.core.types import ArchConfig, WorkerConfig
from repro.models import layers as ML
from repro.models.api import get_ops
from repro.optim import adamw, sgd
from repro.train.sync import StepContext, get_strategy


def make_optimizer(cfg: ArchConfig, base_lr: float = 3e-4,
                   total_steps: int = 10_000):
    lr_fn = make_lr_fn(cfg.lr_schedule,
                       base_lr=1e-3 if cfg.family == "cnn" else base_lr,
                       steps_per_epoch=max(total_steps // 70, 1),
                       total_steps=total_steps)
    if cfg.family == "cnn":
        return sgd(lr_fn)  # paper: plain SGD + decay schedule
    return adamw(lr_fn, moment_dtype=cfg.opt_moment_dtype)


def init_train_state(cfg: ArchConfig, key, sync: SyncConfig,
                     optimizer=None, abstract: bool = False):
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    strat = get_strategy(sync)
    if abstract:
        params = jax.eval_shape(ops.init, key)
    else:
        params = ops.init(key)
    opt_state = (jax.eval_shape(optimizer.init, params) if abstract
                 else optimizer.init(params))
    sync_state = (jax.eval_shape(strat.init_state, params)
                  if abstract else strat.init_state(params))
    return {"params": params, "opt": opt_state, "sync": sync_state,
            "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                     else jnp.zeros((), jnp.int32))}


def state_specs(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Logical PartitionSpec tree matching init_train_state's output."""
    ops = get_ops(cfg)
    pspecs = ops.param_specs()
    optimizer = optimizer or make_optimizer(cfg)
    strat = get_strategy(sync)

    # optimizer state mirrors param sharding (one params-shaped tree per
    # top-level key: adamw {m, v}, sgd-momentum {mu}); the sync strategy
    # owns its own state layout (chaos' ring is τ separate params-shaped
    # slot trees, each sharded exactly like params)
    abstract = jax.eval_shape(ops.init, jax.random.key(0))
    opt_abs = jax.eval_shape(optimizer.init, abstract)
    opt_specs = {k: pspecs for k in opt_abs} if isinstance(opt_abs, dict) else {}
    return {"params": pspecs, "opt": opt_specs,
            "sync": strat.state_specs(pspecs), "step": P()}


def _make_grad_fn(cfg: ArchConfig, ops):
    """(params, batch) -> (loss, metrics, grads), with optional
    microbatching (gradient accumulation): the global batch is split into
    cfg.micro_batches slices processed sequentially — activation memory
    scales 1/n_micro."""
    def grad_fn(params, batch):
        n_micro = max(cfg.micro_batches, 1)
        if n_micro == 1:
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params,
                                                                   batch)
            return l, m, g

        def split(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return (l, m), g

        from repro.models import layers as MLY
        if MLY.UNROLL_ATTN:  # dry-run: unrolled for honest cost accounting
            (l, m), g = one(jax.tree.map(lambda x: x[0], mb))
            for i in range(1, n_micro):
                (li, mi), gi = one(jax.tree.map(lambda x, i=i: x[i], mb))
                l = l + li
                m = jax.tree.map(jnp.add, m, mi)
                g = jax.tree.map(jnp.add, g, gi)
        else:
            def body(carry, b):
                l, m, g = carry
                (li, mi), gi = one(b)
                return (l + li, jax.tree.map(jnp.add, m, mi),
                        jax.tree.map(jnp.add, g, gi)), None
            (l0, m0), g0 = one(jax.tree.map(lambda x: x[0], mb))
            (l, m, g), _ = jax.lax.scan(
                body, (l0, m0, g0), jax.tree.map(lambda x: x[1:], mb))
        inv = 1.0 / n_micro
        return (l * inv, jax.tree.map(lambda t: t * inv, m),
                jax.tree.map(lambda t: t * inv, g))

    return grad_fn


def make_train_step(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns step(state, batch) -> (new_state, metrics).

    The step body comes from the registered SyncStrategy; this builder only
    supplies the single-instance StepContext (implicit-SPMD reductions are
    identities).  ``sync.layerwise`` routes through the per-layer
    non-instant-update path instead (CNN + stateless SGD, DESIGN.md §5).
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    strat = get_strategy(sync)
    if sync.layerwise:
        return _make_layerwise_step(cfg, sync, strat, ops, optimizer)
    ctx = StepContext(optimizer=optimizer, grad_fn=_make_grad_fn(cfg, ops))

    def step(state, batch):
        return strat.step(ctx, state, batch)

    return step


def _make_layerwise_step(cfg: ArchConfig, sync: SyncConfig, strat, ops,
                         optimizer):
    """Per-layer non-instant updates during backprop (paper §3: dW_l is
    applied the moment layer l's gradient is produced, in reverse layer
    order) — works through both the XLA and Pallas-kernel CNN paths, and
    composes with the superstep scan unchanged."""
    if cfg.family != "cnn":
        raise NotImplementedError(
            "sync.layerwise implements the paper's per-layer CNN update "
            f"rule; family={cfg.family!r} has no layerwise backward walk")
    if cfg.micro_batches > 1:
        raise NotImplementedError(
            "sync.layerwise does not compose with micro-batch accumulation")
    if sync.compress:
        raise NotImplementedError(
            "sync.layerwise does not support gradient compression: the "
            "per-layer walk applies raw layer gradients, so the "
            "error-feedback residual would silently never update")
    abstract = jax.eval_shape(ops.init, jax.random.key(0))
    if jax.eval_shape(optimizer.init, abstract) != {}:
        raise NotImplementedError(
            "sync.layerwise applies each layer's update in isolation, which "
            "requires a stateless optimizer (plain SGD, the paper's); got "
            "one with per-parameter state")
    from repro.models.cnn import loss_and_layerwise_update
    ctx = StepContext(optimizer=optimizer)

    def step(state, batch):
        apply_layer, finish = strat.layer_apply(ctx, state["sync"],
                                                state["step"])
        loss, metrics, new_params, grads = loss_and_layerwise_update(
            state["params"], batch, cfg, apply_layer)
        new_sync = finish(grads)
        new_params = strat.boundary(ctx, new_params, state["step"])
        new_state = {"params": new_params, "opt": state["opt"],
                     "sync": new_sync, "step": state["step"] + 1}
        return new_state, {**metrics, "loss": loss}

    return step


def make_superstep(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns superstep(state, batches) -> (new_state, metrics).

    ``batches`` is a stacked (K, B, ...) pytree (``pipeline.superstep_at``);
    the K constituent steps run inside ONE compiled ``jax.lax.scan``, so the
    host dispatches (and syncs on metrics) once per K steps instead of once
    per step.  The whole TrainState — params, optimizer moments, the sync
    strategy's buffers (chaos ring / compression residual), and the step
    counter that drives the LR schedule and localsgd boundary — is the scan
    carry, so every registered strategy composes unchanged and the result
    is bit-identical to K individual dispatches (tests/test_superstep.py).
    Metrics come back stacked (K,).

    jit with ``donate_argnums=(0,)``: the TrainState is donated so a
    superstep is update-in-place at the HBM level.
    """
    step = make_train_step(cfg, sync, optimizer)

    def superstep(state, batches):
        return jax.lax.scan(step, state, batches)

    return superstep


def make_worker_train_step(cfg: ArchConfig, sync: SyncConfig,
                           worker: WorkerConfig, optimizer=None):
    """Per-worker step body for shard_map execution over the worker mesh.

    Runs on each worker's local slice of the global batch (B/N examples,
    contiguous in global batch order).  The local slice is processed as
    ``worker.shards_per_worker`` fixed-size micro-shards via ``lax.map``
    (identical per-shard shapes for every worker count), and the strategy's
    collectives thread over ``worker.axis`` through the StepContext
    reducers:

      combine     - the worker-count-invariant gathered shard mean
                    (all_gather + ONE fixed-shape sum over logical_shards)
      local_mean  - mean over this worker's own micro-shards
      local_frac  - this worker's additive term of the global mean
                    (local shard sum / logical_shards)
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    if sync.compress:
        raise NotImplementedError(
            "gradient compression is not supported on the worker-mesh path")
    if sync.layerwise:
        raise NotImplementedError(
            "sync.layerwise is not supported on the worker-mesh path yet: "
            "the fixed-shape gathered reduction runs on the stacked "
            "micro-shard gradients, and applying it per layer would need "
            "per-layer collectives (ROADMAP open item)")
    if cfg.micro_batches > 1:
        raise NotImplementedError(
            "cfg.micro_batches is not consulted on the worker-mesh path — "
            "the logical-shard decomposition IS the microbatching here "
            "(per-shard batch = B / logical_shards); raise "
            "WorkerConfig.logical_shards to shrink per-shard activation "
            "memory instead")
    if sync.axis_name != worker.axis:
        sync = dataclasses.replace(sync, axis_name=worker.axis)
    strat = get_strategy(sync)
    N, S, axis = worker.workers, worker.logical_shards, worker.axis
    s_local = worker.shards_per_worker

    def shard_grads(params, batch):
        """(losses, metrics, grads), each stacked (S/N, ...) over this
        worker's micro-shards.  Per-shard shapes are independent of N, so
        per-shard values are bit-identical for every worker count."""
        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return l, m, g
        shards = jax.tree.map(
            lambda x: x.reshape((s_local, x.shape[0] // s_local)
                                + x.shape[1:]), batch)
        return jax.lax.map(one, shards)

    ctx = StepContext(
        optimizer=optimizer, grad_fn=shard_grads,
        combine=lambda t: gathered_shard_mean(t, axis, N, S),
        local_mean=lambda t: jax.tree.map(
            lambda x: jnp.sum(x, 0) / s_local, t),
        # sum * (1/S), NOT sum / S: gathered_shard_mean multiplies by the
        # reciprocal, and the hogwild own/remote decomposition must use the
        # same arithmetic so remote_now == 0 exactly when all shards are
        # local (N=1 chaos == bsp for ANY logical_shards, not just pow2)
        local_frac=lambda t: jax.tree.map(
            lambda x: jnp.sum(x, 0) * (1.0 / S), t),
        explicit_workers=True, axis=axis, n_workers=N)

    def step(state, batch):
        return strat.step(ctx, state, batch)

    return step


def init_worker_state(cfg: ArchConfig, key, sync: SyncConfig,
                      worker: WorkerConfig, optimizer=None):
    """TrainState for the worker-mesh route.  Strategies whose workers stay
    provably identical (bsp, chaos τ=0) keep UNSTACKED (mesh-replicated)
    state — byte-for-byte the same checkpoint layout as a single-device
    run, which is what makes those checkpoints worker-count-invariant.
    Strategies whose workers genuinely diverge (localsgd, chaos τ>=1)
    carry a leading (N, ...) worker axis."""
    from repro.core.chaos import replicate_for_workers

    state = init_train_state(cfg, key, sync, optimizer)
    if get_strategy(sync).stacked_state:
        state = replicate_for_workers(state, worker.workers)
    return state


def make_worker_superstep(cfg: ArchConfig, sync: SyncConfig,
                          worker: WorkerConfig, mesh, optimizer=None):
    """Superstep over the worker mesh: the K-step ``lax.scan`` runs INSIDE
    ``shard_map`` over ``mesh``'s 1-D worker axis, so per-step collectives
    (gradient exchange / boundary averages) stay on-device across all K
    steps and the host still dispatches once per superstep.

    Call with the GLOBAL stacked (K, B, ...) batch; shard_map splits axis 1
    over workers (worker w's slice == ``pipeline.worker_superstep_at(step,
    k, N, w)``).  State specs follow ``init_worker_state``'s layout — the
    strategy's ``shard_view`` (replicated or worker-stacked).  Metrics are
    replicated (K,) vectors.  jit'd with the TrainState donated.
    """
    from jax.experimental.shard_map import shard_map

    step = make_worker_train_step(cfg, sync, worker, optimizer)
    strat = get_strategy(sync)
    stacked = strat.stacked_state

    def superstep(state, batches):
        if stacked:
            state = jax.tree.map(lambda x: x[0], state)
        state, metrics = jax.lax.scan(step, state, batches)
        if stacked:
            state = jax.tree.map(lambda x: x[None], state)
        return state, metrics

    state_spec = strat.shard_view(worker)
    fn = shard_map(superstep, mesh=mesh,
                   in_specs=(state_spec, P(None, worker.axis)),
                   out_specs=(state_spec, P()),
                   check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))


def make_serve_step(cfg: ArchConfig):
    ops = get_ops(cfg)

    def serve_step(params, cache, tokens, cache_len):
        logits, new_cache = ops.decode(params, cache, tokens, cache_len)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), new_cache
    return serve_step
