"""Train/serve step builders: the glue between models, CHAOS sync,
optimizers, and sharding.

``make_train_step(cfg, sync)``  -> (step_fn, TrainState helpers)
``make_superstep(cfg, sync)``   -> K steps per dispatch via lax.scan over a
                                   stacked (K, B, ...) batch (DESIGN.md §3)
``make_serve_step(cfg)``        -> decode step over a KV/state cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.chaos import (SyncConfig, gathered_shard_mean,
                              init_sync_state, localsgd_average,
                              replicate_for_workers, transform_grads)
from repro.core.schedule import make_lr_fn
from repro.core.types import ArchConfig, WorkerConfig
from repro.models import layers as ML
from repro.models.api import get_ops
from repro.optim import adamw, sgd


def make_optimizer(cfg: ArchConfig, base_lr: float = 3e-4,
                   total_steps: int = 10_000):
    lr_fn = make_lr_fn(cfg.lr_schedule,
                       base_lr=1e-3 if cfg.family == "cnn" else base_lr,
                       steps_per_epoch=max(total_steps // 70, 1),
                       total_steps=total_steps)
    if cfg.family == "cnn":
        return sgd(lr_fn)  # paper: plain SGD + decay schedule
    return adamw(lr_fn, moment_dtype=cfg.opt_moment_dtype)


def init_train_state(cfg: ArchConfig, key, sync: SyncConfig,
                     optimizer=None, abstract: bool = False):
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    if abstract:
        params = jax.eval_shape(ops.init, key)
    else:
        params = ops.init(key)
    opt_state = (jax.eval_shape(optimizer.init, params) if abstract
                 else optimizer.init(params))
    sync_state = (jax.eval_shape(lambda p: init_sync_state(sync, p), params)
                  if abstract else init_sync_state(sync, params))
    return {"params": params, "opt": opt_state, "sync": sync_state,
            "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                     else jnp.zeros((), jnp.int32))}


def state_specs(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Logical PartitionSpec tree matching init_train_state's output."""
    ops = get_ops(cfg)
    pspecs = ops.param_specs()
    optimizer = optimizer or make_optimizer(cfg)

    # optimizer / sync states mirror param sharding (one params-shaped tree
    # per top-level key: adamw {m, v}, sgd-momentum {mu}, chaos {prev_grad})
    abstract = jax.eval_shape(ops.init, jax.random.key(0))
    opt_abs = jax.eval_shape(optimizer.init, abstract)
    sync_abs = jax.eval_shape(lambda p: init_sync_state(sync, p), abstract)
    opt_specs = {k: pspecs for k in opt_abs} if isinstance(opt_abs, dict) else {}
    # params-shaped sync buffers mirror param sharding; scalar carries
    # (localsgd's local_t counter) are replicated
    sync_specs = {k: (pspecs if isinstance(v, dict) else P())
                  for k, v in sync_abs.items()}
    return {"params": pspecs, "opt": opt_specs, "sync": sync_specs,
            "step": P()}


def make_train_step(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns step(state, batch) -> (new_state, metrics).

    CHAOS mode: apply the previous step's (already-reduced) gradients first,
    then compute this step's gradients — their cross-replica reduction gates
    only the step output, so it overlaps with compute (DESIGN.md §2).
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)

    def grad_fn(params, batch):
        """Gradients, with optional microbatching (gradient accumulation):
        the global batch is split into cfg.micro_batches slices processed
        sequentially — activation memory scales 1/n_micro."""
        n_micro = max(cfg.micro_batches, 1)
        if n_micro == 1:
            return jax.value_and_grad(ops.loss, has_aux=True)(params, batch)

        def split(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return (l, m), g

        from repro.models import layers as MLY
        if MLY.UNROLL_ATTN:  # dry-run: unrolled for honest cost accounting
            (l, m), g = one(jax.tree.map(lambda x: x[0], mb))
            for i in range(1, n_micro):
                (li, mi), gi = one(jax.tree.map(lambda x, i=i: x[i], mb))
                l = l + li
                m = jax.tree.map(jnp.add, m, mi)
                g = jax.tree.map(jnp.add, g, gi)
        else:
            def body(carry, b):
                l, m, g = carry
                (li, mi), gi = one(b)
                return (l + li, jax.tree.map(jnp.add, m, mi),
                        jax.tree.map(jnp.add, g, gi)), None
            (l0, m0), g0 = one(jax.tree.map(lambda x: x[0], mb))
            (l, m, g), _ = jax.lax.scan(
                body, (l0, m0, g0), jax.tree.map(lambda x: x[1:], mb))
        inv = 1.0 / n_micro
        return ((l * inv, jax.tree.map(lambda t: t * inv, m)),
                jax.tree.map(lambda t: t * inv, g))

    def step(state, batch):
        params = state["params"]

        if sync.mode == "chaos":
            # 1) update with the stale (previous-step) global gradient —
            #    available immediately, no blocking collective
            g_apply = state["sync"]["prev_grad"]
            new_params, new_opt = optimizer.apply(params, g_apply,
                                                  state["opt"], state["step"])
            # 2) fresh gradients at the new params -> next step's update;
            #    their reduction gates only the step OUTPUT (overlappable)
            (loss, metrics), grads = grad_fn(new_params, batch)
            new_sync = dict(state["sync"])
            if sync.compress:
                from repro.core.chaos import compress_grads
                grads, new_sync["residual"] = compress_grads(
                    grads, state["sync"]["residual"])
            new_sync["prev_grad"] = jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, new_params)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            g_apply, new_sync = transform_grads(sync, grads, state["sync"])
            new_params, new_opt = optimizer.apply(params, g_apply,
                                                  state["opt"], state["step"])
            if sync.mode == "localsgd":
                # strategy-C boundary: average params every local_steps,
                # keyed off the scan-carried step counter
                new_params = localsgd_average(sync, new_params,
                                              state["step"])

        new_state = {"params": new_params, "opt": new_opt, "sync": new_sync,
                     "step": state["step"] + 1}
        metrics = {**metrics, "loss": loss}
        return new_state, metrics

    return step


def make_superstep(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns superstep(state, batches) -> (new_state, metrics).

    ``batches`` is a stacked (K, B, ...) pytree (``pipeline.superstep_at``);
    the K constituent steps run inside ONE compiled ``jax.lax.scan``, so the
    host dispatches (and syncs on metrics) once per K steps instead of once
    per step.  The whole TrainState — params, optimizer moments, CHAOS sync
    buffers (prev_grad / residual), and the step counter that drives the
    LR schedule and localsgd boundary — is the scan carry, so all sync modes
    compose unchanged and the result is bit-identical to K individual
    dispatches (tests/test_superstep.py).  Metrics come back stacked (K,).

    jit with ``donate_argnums=(0,)``: the TrainState is donated so a
    superstep is update-in-place at the HBM level.
    """
    step = make_train_step(cfg, sync, optimizer)

    def superstep(state, batches):
        return jax.lax.scan(step, state, batches)

    return superstep


def make_worker_train_step(cfg: ArchConfig, sync: SyncConfig,
                           worker: WorkerConfig, optimizer=None):
    """Per-worker step body for shard_map execution over the worker mesh.

    Runs on each worker's local slice of the global batch (B/N examples,
    contiguous in global batch order).  The local slice is processed as
    ``worker.shards_per_worker`` fixed-size micro-shards via ``lax.map``
    (identical per-shard shapes for every worker count), and the CHAOS sync
    modes thread their collectives over ``worker.axis``:

      bsp      - gradients all_gather'd and reduced with the fixed-shape
                 shard mean (worker-count-invariant, bit-exact across N);
                 workers stay identical.
      chaos    - staleness-1 delayed exchange: apply the previous step's
                 globally-reduced gradient (no blocking collective), then
                 compute fresh gradients whose all_gather gates only the
                 step output; workers stay identical.
      localsgd - purely local gradients; parameters pmean-averaged over the
                 worker axis every ``sync.local_steps`` steps (workers
                 diverge between boundaries).
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    if sync.compress:
        raise NotImplementedError(
            "gradient compression is not supported on the worker-mesh path")
    if cfg.micro_batches > 1:
        raise NotImplementedError(
            "cfg.micro_batches is not consulted on the worker-mesh path — "
            "the logical-shard decomposition IS the microbatching here "
            "(per-shard batch = B / logical_shards); raise "
            "WorkerConfig.logical_shards to shrink per-shard activation "
            "memory instead")
    if sync.mode == "localsgd" and sync.axis_name != worker.axis:
        sync = dataclasses.replace(sync, axis_name=worker.axis)
    N, S, axis = worker.workers, worker.logical_shards, worker.axis
    s_local = worker.shards_per_worker

    def shard_grads(params, batch):
        """(losses, metrics, grads), each stacked (S/N, ...) over this
        worker's micro-shards.  Per-shard shapes are independent of N, so
        per-shard values are bit-identical for every worker count."""
        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return l, m, g
        shards = jax.tree.map(
            lambda x: x.reshape((s_local, x.shape[0] // s_local)
                                + x.shape[1:]), batch)
        return jax.lax.map(one, shards)

    def global_mean(tree):
        return gathered_shard_mean(tree, axis, N, S)

    def step(state, batch):
        params = state["params"]

        if sync.mode == "chaos":
            # staleness-1: apply last step's (already-reduced) global
            # gradient now, compute fresh local gradients after — their
            # all_gather gates only this step's OUTPUT (overlappable)
            g_apply = state["sync"]["prev_grad"]
            new_params, new_opt = optimizer.apply(params, g_apply,
                                                  state["opt"], state["step"])
            losses, metrics, grads = shard_grads(new_params, batch)
            new_sync = dict(state["sync"])
            new_sync["prev_grad"] = jax.tree.map(
                lambda g, p: g.astype(p.dtype), global_mean(grads),
                new_params)
        elif sync.mode == "bsp":
            losses, metrics, grads = shard_grads(params, batch)
            new_params, new_opt = optimizer.apply(params, global_mean(grads),
                                                  state["opt"], state["step"])
            new_sync = dict(state["sync"])
        elif sync.mode == "localsgd":
            losses, metrics, grads = shard_grads(params, batch)
            g_local = jax.tree.map(lambda x: jnp.sum(x, 0) / s_local, grads)
            new_params, new_opt = optimizer.apply(params, g_local,
                                                  state["opt"], state["step"])
            new_params = localsgd_average(sync, new_params, state["step"])
            new_sync = dict(state["sync"])
        else:
            raise ValueError(sync.mode)

        packed = {**metrics, "loss": losses}
        if sync.mode == "localsgd":
            packed = jax.tree.map(lambda x: jnp.mean(x, 0), packed)
            packed = jax.lax.pmean(packed, axis) if N > 1 else packed
        else:
            # same fixed-shape reduction as the gradients: the logged loss
            # is bit-identical across worker counts too
            packed = global_mean(packed)
        new_state = {"params": new_params, "opt": new_opt, "sync": new_sync,
                     "step": state["step"] + 1}
        return new_state, packed

    return step


def init_worker_state(cfg: ArchConfig, key, sync: SyncConfig,
                      worker: WorkerConfig, optimizer=None):
    """TrainState for the worker-mesh route.  bsp/chaos keep every worker
    identical, so their state is UNSTACKED (mesh-replicated) — byte-for-byte
    the same checkpoint layout as a single-device run, which is what makes
    bsp checkpoints worker-count-invariant.  localsgd workers genuinely
    diverge between K-boundaries, so its state carries a leading (N, ...)
    worker axis."""
    state = init_train_state(cfg, key, sync, optimizer)
    if sync.mode == "localsgd":
        state = replicate_for_workers(state, worker.workers)
    return state


def make_worker_superstep(cfg: ArchConfig, sync: SyncConfig,
                          worker: WorkerConfig, mesh, optimizer=None):
    """Superstep over the worker mesh: the K-step ``lax.scan`` runs INSIDE
    ``shard_map`` over ``mesh``'s 1-D worker axis, so per-step collectives
    (gradient exchange / localsgd boundary averages) stay on-device across
    all K steps and the host still dispatches once per superstep.

    Call with the GLOBAL stacked (K, B, ...) batch; shard_map splits axis 1
    over workers (worker w's slice == ``pipeline.worker_superstep_at(step,
    k, N, w)``).  State specs follow ``init_worker_state``'s layout:
    replicated for bsp/chaos, worker-sharded for localsgd.  Metrics are
    replicated (K,) vectors.  jit'd with the TrainState donated.
    """
    from jax.experimental.shard_map import shard_map

    step = make_worker_train_step(cfg, sync, worker, optimizer)
    stacked = sync.mode == "localsgd"
    axis = worker.axis

    def superstep(state, batches):
        if stacked:
            state = jax.tree.map(lambda x: x[0], state)
        state, metrics = jax.lax.scan(step, state, batches)
        if stacked:
            state = jax.tree.map(lambda x: x[None], state)
        return state, metrics

    state_spec = P(axis) if stacked else P()
    fn = shard_map(superstep, mesh=mesh,
                   in_specs=(state_spec, P(None, axis)),
                   out_specs=(state_spec, P()),
                   check_rep=False)
    return jax.jit(fn, donate_argnums=(0,))


def make_serve_step(cfg: ArchConfig):
    ops = get_ops(cfg)

    def serve_step(params, cache, tokens, cache_len):
        logits, new_cache = ops.decode(params, cache, tokens, cache_len)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), new_cache

    return serve_step
