"""Train/serve step builders: the glue between models, CHAOS sync,
optimizers, and sharding.

``make_train_step(cfg, sync)``  -> (step_fn, TrainState helpers)
``make_superstep(cfg, sync)``   -> K steps per dispatch via lax.scan over a
                                   stacked (K, B, ...) batch (DESIGN.md §3)
``make_serve_step(cfg)``        -> decode step over a KV/state cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.chaos import (SyncConfig, init_sync_state, localsgd_average,
                              transform_grads)
from repro.core.schedule import make_lr_fn
from repro.core.types import ArchConfig
from repro.models import layers as ML
from repro.models.api import get_ops
from repro.optim import adamw, sgd


def make_optimizer(cfg: ArchConfig, base_lr: float = 3e-4,
                   total_steps: int = 10_000):
    lr_fn = make_lr_fn(cfg.lr_schedule,
                       base_lr=1e-3 if cfg.family == "cnn" else base_lr,
                       steps_per_epoch=max(total_steps // 70, 1),
                       total_steps=total_steps)
    if cfg.family == "cnn":
        return sgd(lr_fn)  # paper: plain SGD + decay schedule
    return adamw(lr_fn, moment_dtype=cfg.opt_moment_dtype)


def init_train_state(cfg: ArchConfig, key, sync: SyncConfig,
                     optimizer=None, abstract: bool = False):
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)
    if abstract:
        params = jax.eval_shape(ops.init, key)
    else:
        params = ops.init(key)
    opt_state = (jax.eval_shape(optimizer.init, params) if abstract
                 else optimizer.init(params))
    sync_state = (jax.eval_shape(lambda p: init_sync_state(sync, p), params)
                  if abstract else init_sync_state(sync, params))
    return {"params": params, "opt": opt_state, "sync": sync_state,
            "step": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                     else jnp.zeros((), jnp.int32))}


def state_specs(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Logical PartitionSpec tree matching init_train_state's output."""
    ops = get_ops(cfg)
    pspecs = ops.param_specs()
    optimizer = optimizer or make_optimizer(cfg)

    # optimizer / sync states mirror param sharding (one params-shaped tree
    # per top-level key: adamw {m, v}, sgd-momentum {mu}, chaos {prev_grad})
    abstract = jax.eval_shape(ops.init, jax.random.key(0))
    opt_abs = jax.eval_shape(optimizer.init, abstract)
    sync_abs = jax.eval_shape(lambda p: init_sync_state(sync, p), abstract)
    opt_specs = {k: pspecs for k in opt_abs} if isinstance(opt_abs, dict) else {}
    # params-shaped sync buffers mirror param sharding; scalar carries
    # (localsgd's local_t counter) are replicated
    sync_specs = {k: (pspecs if isinstance(v, dict) else P())
                  for k, v in sync_abs.items()}
    return {"params": pspecs, "opt": opt_specs, "sync": sync_specs,
            "step": P()}


def make_train_step(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns step(state, batch) -> (new_state, metrics).

    CHAOS mode: apply the previous step's (already-reduced) gradients first,
    then compute this step's gradients — their cross-replica reduction gates
    only the step output, so it overlaps with compute (DESIGN.md §2).
    """
    ops = get_ops(cfg)
    optimizer = optimizer or make_optimizer(cfg)

    def grad_fn(params, batch):
        """Gradients, with optional microbatching (gradient accumulation):
        the global batch is split into cfg.micro_batches slices processed
        sequentially — activation memory scales 1/n_micro."""
        n_micro = max(cfg.micro_batches, 1)
        if n_micro == 1:
            return jax.value_and_grad(ops.loss, has_aux=True)(params, batch)

        def split(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def one(b):
            (l, m), g = jax.value_and_grad(ops.loss, has_aux=True)(params, b)
            g = jax.tree.map(lambda t: t.astype(jnp.float32), g)
            return (l, m), g

        from repro.models import layers as MLY
        if MLY.UNROLL_ATTN:  # dry-run: unrolled for honest cost accounting
            (l, m), g = one(jax.tree.map(lambda x: x[0], mb))
            for i in range(1, n_micro):
                (li, mi), gi = one(jax.tree.map(lambda x, i=i: x[i], mb))
                l = l + li
                m = jax.tree.map(jnp.add, m, mi)
                g = jax.tree.map(jnp.add, g, gi)
        else:
            def body(carry, b):
                l, m, g = carry
                (li, mi), gi = one(b)
                return (l + li, jax.tree.map(jnp.add, m, mi),
                        jax.tree.map(jnp.add, g, gi)), None
            (l0, m0), g0 = one(jax.tree.map(lambda x: x[0], mb))
            (l, m, g), _ = jax.lax.scan(
                body, (l0, m0, g0), jax.tree.map(lambda x: x[1:], mb))
        inv = 1.0 / n_micro
        return ((l * inv, jax.tree.map(lambda t: t * inv, m)),
                jax.tree.map(lambda t: t * inv, g))

    def step(state, batch):
        params = state["params"]

        if sync.mode == "chaos":
            # 1) update with the stale (previous-step) global gradient —
            #    available immediately, no blocking collective
            g_apply = state["sync"]["prev_grad"]
            new_params, new_opt = optimizer.apply(params, g_apply,
                                                  state["opt"], state["step"])
            # 2) fresh gradients at the new params -> next step's update;
            #    their reduction gates only the step OUTPUT (overlappable)
            (loss, metrics), grads = grad_fn(new_params, batch)
            new_sync = dict(state["sync"])
            if sync.compress:
                from repro.core.chaos import compress_grads
                grads, new_sync["residual"] = compress_grads(
                    grads, state["sync"]["residual"])
            new_sync["prev_grad"] = jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, new_params)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            g_apply, new_sync = transform_grads(sync, grads, state["sync"])
            new_params, new_opt = optimizer.apply(params, g_apply,
                                                  state["opt"], state["step"])
            if sync.mode == "localsgd":
                # strategy-C boundary: average params every local_steps,
                # keyed off the scan-carried step counter
                new_params = localsgd_average(sync, new_params,
                                              state["step"])

        new_state = {"params": new_params, "opt": new_opt, "sync": new_sync,
                     "step": state["step"] + 1}
        metrics = {**metrics, "loss": loss}
        return new_state, metrics

    return step


def make_superstep(cfg: ArchConfig, sync: SyncConfig, optimizer=None):
    """Returns superstep(state, batches) -> (new_state, metrics).

    ``batches`` is a stacked (K, B, ...) pytree (``pipeline.superstep_at``);
    the K constituent steps run inside ONE compiled ``jax.lax.scan``, so the
    host dispatches (and syncs on metrics) once per K steps instead of once
    per step.  The whole TrainState — params, optimizer moments, CHAOS sync
    buffers (prev_grad / residual), and the step counter that drives the
    LR schedule and localsgd boundary — is the scan carry, so all sync modes
    compose unchanged and the result is bit-identical to K individual
    dispatches (tests/test_superstep.py).  Metrics come back stacked (K,).

    jit with ``donate_argnums=(0,)``: the TrainState is donated so a
    superstep is update-in-place at the HBM level.
    """
    step = make_train_step(cfg, sync, optimizer)

    def superstep(state, batches):
        return jax.lax.scan(step, state, batches)

    return superstep


def make_serve_step(cfg: ArchConfig):
    ops = get_ops(cfg)

    def serve_step(params, cache, tokens, cache_len):
        logits, new_cache = ops.decode(params, cache, tokens, cache_len)
        next_tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), new_cache

    return serve_step
