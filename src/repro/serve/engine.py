"""Continuous-batching serve engine (DESIGN.md §9).

The CHAOS mapping: training kept every lane busy with thread+vector
parallelism; serving keeps the device busy by stepping ALL occupied cache
slots in one fixed-shape compiled dispatch per token, admitting queued
requests into free slots mid-flight (batched prefill) and evicting
finished sequences without recompiling anything.

Scheduler loop (one ``step()``):
  1. admit  — pop every arrived request that fits a free slot, prefill the
     group in ONE dispatch (whole right-padded prompts; ``q_offset`` keeps
     the causal mask honest), scatter the sub-cache into the slots, and
     take each row's first sampled token from the prefill logits at
     ``lengths-1`` — the prefill dispatch IS that token's decode.
  2. decode — one compiled dispatch over the whole slot batch with the
     per-slot cursor vector as ``cache_len``; greedy sampling is fused
     into the dispatch (no eager host-side argmax), so a request that
     generates ``gen`` tokens costs exactly 1 prefill + (gen-1) decode
     dispatches — the old per-token loop paid one extra trailing decode
     whose logits were discarded, plus a host sync per token.
  3. evict  — slots whose request hit ``max_new`` go back to the free
     list; idle slots keep decoding junk (harmless: causal rows are never
     fully masked, and admission overwrites the whole slot row).

Determinism: admission time is VIRTUAL (``step_dt`` seconds of clock per
decode step), sampling is greedy, and every per-row computation is
independent of its batch neighbours — so a (seed, trace) pair generates
identical tokens regardless of slot count or admission interleaving.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models.api import get_ops


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    max_new: int
    arrival: float = 0.0        # virtual seconds


@dataclasses.dataclass
class Finished:
    rid: int
    prompt_len: int
    tokens: np.ndarray          # (n_generated,) int32
    admit_step: int
    finish_step: int


def poisson_trace(seed: int, n: int, rate: float, vocab: int,
                  prompt_lens=(8, 32), max_new: int = 8) -> list:
    """Seeded Poisson request trace: exponential inter-arrivals at ``rate``
    requests per virtual second, uniform prompt lengths in ``prompt_lens``
    (inclusive), random token ids.  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    lo, hi = prompt_lens
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        ln = int(rng.integers(lo, hi + 1))
        toks = rng.integers(0, vocab, size=(ln,)).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new=max_new, arrival=t))
    return reqs


class RequestFeed(threading.Thread):
    """Producer side of the feed/compute split (the superstep PrefetchFeed
    idiom from launch/train.py): replays a trace into a bounded queue so
    request ingest (tokenize/IO stand-in) overlaps the device loop.  With
    ``realtime=True`` it sleeps until each request's (scaled) arrival."""

    def __init__(self, trace, depth: int = 64, realtime: bool = False,
                 time_scale: float = 0.0):
        super().__init__(daemon=True)
        self.q = queue.Queue(maxsize=depth)
        self._trace = list(trace)
        self._realtime = realtime
        self._scale = time_scale
        self._stop = threading.Event()

    def run(self):
        t0 = time.time()
        for req in self._trace:
            if self._stop.is_set():
                return
            if self._realtime:
                lag = req.arrival * self._scale - (time.time() - t0)
                if lag > 0:
                    time.sleep(lag)
            self.q.put(req)
        self.q.put(None)                     # sentinel: trace exhausted

    def stop(self):
        self._stop.set()

    def drain(self) -> list:
        """Non-blocking: every request available right now."""
        out = []
        while True:
            try:
                item = self.q.get_nowait()
            except queue.Empty:
                return out
            if item is None:
                return out
            out.append(item)


def _pow2_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ServeEngine:
    """Continuous-batching engine over one model family.

    ``prefill_mode``: 'batched' (whole prompts, one dispatch — the fast
    path) or 'loop' (token-at-a-time reference, the pre-§9 serve loop,
    kept as the benchmark baseline).  ``use_kernel`` routes GQA prefill
    attention through the Pallas flash kernel (interpret-mode on CPU)."""

    def __init__(self, arch: str, *, slots: int = 4, max_seq: int = 128,
                 smoke: bool = True, seed: int = 0, step_dt: float = 1.0,
                 prefill_mode: str = "batched", use_kernel: bool = False,
                 params=None):
        from repro.serve.cache import SlotKVCache
        if prefill_mode not in ("batched", "loop"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.cfg = C.smoke(arch) if smoke else C.get(arch)
        self.ops = get_ops(self.cfg)
        if self.ops.decode is None or self.ops.prefill is None:
            raise ValueError(f"{arch} ({self.cfg.family}) is not servable")
        self.params = (params if params is not None
                       else self.ops.init(jax.random.key(seed)))
        self.kv = SlotKVCache(self.ops, slots, max_seq)
        self.prefill_mode = prefill_mode
        self.use_kernel = use_kernel
        self.step_dt = step_dt
        self.clock = 0.0
        self.step_idx = 0
        self.pending: list = []              # sorted by arrival
        self.active: dict = {}               # slot -> state dict
        self.counters = {"prefill_dispatch": 0, "decode_dispatch": 0,
                         "prefill_tokens": 0, "decode_tokens": 0}
        self.last_tok = np.zeros((slots, 1), np.int32)
        self._prefill_jit: dict = {}
        vocab = self.cfg.vocab_size

        def _decode(params, cache, toks, cursors):
            logits, cache = self.ops.decode(params, cache, toks, cursors)
            nxt = jnp.argmax(logits[:, -1, :vocab], axis=-1)
            return nxt.astype(jnp.int32)[:, None], cache

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        # token-at-a-time reference prefill step (cache_len as a traced
        # scalar so one program serves every position)
        self._decode_t1 = jax.jit(
            lambda p, c, t, cl: self.ops.decode(p, c, t, cl))

    # -- prefill ------------------------------------------------------------
    def _prefill_fn(self, A: int, T: int):
        key = (A, T)
        if key in self._prefill_jit:
            return self._prefill_jit[key]
        ops, vocab = self.ops, self.cfg.vocab_size
        kw = ({"use_kernel": True} if self.use_kernel
              and self.cfg.family == "dense" else {})

        def fn(params, tokens, lengths):
            sub = self.kv.zeros_like_sub(ops, A)
            logits, sub = ops.prefill(params, sub, tokens, lengths, 0, **kw)
            rows = jnp.arange(A)
            nxt = jnp.argmax(logits[rows, lengths - 1, :vocab], axis=-1)
            return nxt.astype(jnp.int32)[:, None], sub

        self._prefill_jit[key] = jax.jit(fn)
        return self._prefill_jit[key]

    def _admit(self, reqs) -> None:
        slots = self.kv.alloc(len(reqs))
        lens = np.array([len(r.tokens) for r in reqs], np.int32)
        if self.prefill_mode == "batched":
            T = _pow2_bucket(int(lens.max()))
            if not self.kv.stateful:
                # bucket padding writes [0, T) into every row's KV slot, so
                # the bucket itself must fit (admitted rows already do)
                T = min(T, self.kv.max_seq)
            toks = np.zeros((len(reqs), T), np.int32)
            for i, r in enumerate(reqs):
                toks[i, :lens[i]] = r.tokens
            first, sub = self._prefill_fn(len(reqs), T)(
                self.params, jnp.asarray(toks), jnp.asarray(lens))
            self.counters["prefill_dispatch"] += 1
            self.kv.adopt(sub, slots, lens)
            first = np.asarray(first)
        else:                                # token-at-a-time reference loop
            first = np.zeros((len(reqs), 1), np.int32)
            sub_rows = []
            for i, r in enumerate(reqs):
                logits = None
                row = self.kv.zeros_like_sub(self.ops, 1)
                for t in range(lens[i]):
                    tok = jnp.asarray(r.tokens[t:t + 1][None])
                    logits, row = self._decode_t1(
                        self.params, row, tok, jnp.int32(t))
                    self.counters["prefill_dispatch"] += 1
                first[i, 0] = int(jnp.argmax(
                    logits[0, -1, :self.cfg.vocab_size]))
                sub_rows.append(row)
            sub = jax.tree.map(lambda *xs: jnp.concatenate(xs, 1), *sub_rows)
            self.kv.adopt(sub, slots, lens)
        self.counters["prefill_tokens"] += int(lens.sum())
        for i, (r, s) in enumerate(zip(reqs, slots)):
            self.last_tok[s, 0] = first[i, 0]
            self.active[s] = {"req": r, "out": [int(first[i, 0])],
                              "admit_step": self.step_idx}

    # -- scheduler ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.kv.validate_admit(len(req.tokens), req.max_new)
        self.pending.append(req)
        self.pending.sort(key=lambda r: (r.arrival, r.rid))

    def _evict_done(self) -> list:
        done = []
        for slot in sorted(self.active):
            st = self.active[slot]
            if len(st["out"]) >= st["req"].max_new:
                done.append(Finished(
                    rid=st["req"].rid, prompt_len=len(st["req"].tokens),
                    tokens=np.array(st["out"], np.int32),
                    admit_step=st["admit_step"], finish_step=self.step_idx))
                del self.active[slot]
                self.kv.release(slot)
        return done

    def step(self) -> list:
        """One scheduler step: admit -> (maybe) decode -> evict.  Returns
        requests finished during this step."""
        if not self.active and self.pending:
            # idle engine: jump the virtual clock to the next arrival
            self.clock = max(self.clock, self.pending[0].arrival)
        grab = []
        while (self.pending and self.kv.free_count() > len(grab)
               and self.pending[0].arrival <= self.clock):
            grab.append(self.pending.pop(0))
        if grab:
            self._admit(grab)
        done = self._evict_done()            # max_new == 1 finishes here
        if not self.active:
            self.clock += self.step_dt
            self.step_idx += 1
            return done
        nxt, self.kv.tree = self._decode(
            self.params, self.kv.tree, jnp.asarray(self.last_tok),
            jnp.asarray(self.kv.cursors))
        self.counters["decode_dispatch"] += 1
        nxt = np.asarray(nxt)                # sync point (sampled on-device)
        for slot, st in self.active.items():
            self.kv.cursors[slot] += 1
            st["out"].append(int(nxt[slot, 0]))
            self.last_tok[slot, 0] = nxt[slot, 0]
        self.counters["decode_tokens"] += len(self.active)
        done += self._evict_done()
        self.clock += self.step_dt
        self.step_idx += 1
        return done

    def run(self, trace=None) -> list:
        """Drive until every submitted/traced request finishes."""
        for r in (trace or []):
            self.submit(r)
        finished = []
        while self.pending or self.active:
            finished.extend(self.step())
        return sorted(finished, key=lambda f: f.rid)
