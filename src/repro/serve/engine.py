"""Continuous-batching serve engine (DESIGN.md §9).

The CHAOS mapping: training kept every lane busy with thread+vector
parallelism; serving keeps the device busy by stepping ALL occupied cache
slots in one fixed-shape compiled dispatch per token, admitting queued
requests into free slots mid-flight (batched prefill) and evicting
finished sequences without recompiling anything.

Scheduler loop (one ``step()``):
  1. admit  — pop every arrived request that fits a free slot, prefill the
     group in ONE dispatch (whole right-padded prompts; ``q_offset`` keeps
     the causal mask honest), scatter the sub-cache into the slots, and
     take each row's first sampled token from the prefill logits at
     ``lengths-1`` — the prefill dispatch IS that token's decode.
  2. decode — one compiled dispatch over the whole slot batch with the
     per-slot cursor vector as ``cache_len``; greedy sampling is fused
     into the dispatch (no eager host-side argmax), so a request that
     generates ``gen`` tokens costs exactly 1 prefill + (gen-1) decode
     dispatches — the old per-token loop paid one extra trailing decode
     whose logits were discarded, plus a host sync per token.
  3. evict  — slots whose request hit ``max_new`` go back to the free
     list; idle slots keep decoding junk (harmless: causal rows are never
     fully masked, and admission overwrites the whole slot row).

Determinism: admission time is VIRTUAL (``step_dt`` seconds of clock per
decode step), sampling is greedy by default, and every per-row computation
is independent of its batch neighbours — so a (seed, trace) pair generates
identical tokens regardless of slot count or admission interleaving.
``temperature`` > 0 enables seeded sampling (optionally top-p nucleus)
fused into the same dispatches; its keys fold (request id, token position),
never the slot index, so the determinism contract survives sampling: same
(seed, trace) ⇒ same tokens, still slot-count-invariant.

Observability (DESIGN.md §11): with a ``tracer``/``bus`` attached the
engine emits the full admit→prefill→decode→evict lifecycle — a
``request/<rid>`` span per request on its slot's track, ``prefill``/
``decode`` dispatch spans on the engine track, slot-occupancy and
queue-depth gauges, TTFT/TPOT histograms, and dispatch/token counters.
Without them, no obs code runs at all.
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models.api import get_ops


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (prompt_len,) int32
    max_new: int
    arrival: float = 0.0        # virtual seconds


@dataclasses.dataclass
class Finished:
    rid: int
    prompt_len: int
    tokens: np.ndarray          # (n_generated,) int32
    admit_step: int
    finish_step: int


def poisson_trace(seed: int, n: int, rate: float, vocab: int,
                  prompt_lens=(8, 32), max_new: int = 8) -> list:
    """Seeded Poisson request trace: exponential inter-arrivals at ``rate``
    requests per virtual second, uniform prompt lengths in ``prompt_lens``
    (inclusive), random token ids.  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    lo, hi = prompt_lens
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        ln = int(rng.integers(lo, hi + 1))
        toks = rng.integers(0, vocab, size=(ln,)).astype(np.int32)
        reqs.append(Request(rid=i, tokens=toks, max_new=max_new, arrival=t))
    return reqs


class RequestFeed(threading.Thread):
    """Producer side of the feed/compute split (the superstep PrefetchFeed
    idiom from launch/train.py): replays a trace into a bounded queue so
    request ingest (tokenize/IO stand-in) overlaps the device loop.  With
    ``realtime=True`` it sleeps until each request's (scaled) arrival."""

    def __init__(self, trace, depth: int = 64, realtime: bool = False,
                 time_scale: float = 0.0):
        super().__init__(daemon=True)
        self.q = queue.Queue(maxsize=depth)
        self._trace = list(trace)
        self._realtime = realtime
        self._scale = time_scale
        self._stop = threading.Event()

    def run(self):
        t0 = time.time()
        for req in self._trace:
            if self._stop.is_set():
                return
            if self._realtime:
                lag = req.arrival * self._scale - (time.time() - t0)
                if lag > 0:
                    time.sleep(lag)
            self.q.put(req)
        self.q.put(None)                     # sentinel: trace exhausted

    def stop(self):
        self._stop.set()

    def drain(self) -> list:
        """Non-blocking: every request available right now."""
        out = []
        while True:
            try:
                item = self.q.get_nowait()
            except queue.Empty:
                return out
            if item is None:
                return out
            out.append(item)


def _pow2_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _make_sampler(temperature: float, top_p: float):
    """Seeded per-row sampler fused into the decode/prefill dispatches, or
    None for greedy (``temperature <= 0``).  Each row's key folds
    (request id, generated-token position) — never the slot index or the
    batch composition — so sampled tokens are deterministic in (seed,
    trace) and invariant to slot count, exactly like the greedy path."""
    if temperature <= 0.0:
        return None

    def sample_row(key, logits):
        l = logits.astype(jnp.float32) / jnp.float32(temperature)
        if top_p < 1.0:
            order = jnp.argsort(-l)
            ls = l[order]
            ps = jax.nn.softmax(ls)
            # nucleus: keep tokens whose PRECEDING cumulative mass < top_p
            # (the head token always survives, so the mask can't be empty)
            mass_before = jnp.cumsum(ps) - ps
            ls = jnp.where(mass_before < top_p, ls, -jnp.inf)
            return order[jax.random.categorical(key, ls)]
        return jax.random.categorical(key, l)

    def sample(base_key, rids, positions, logits):
        def one(rid, pos, lg):
            k = jax.random.fold_in(jax.random.fold_in(base_key, rid), pos)
            return sample_row(k, lg)
        return jax.vmap(one)(rids, positions, logits)

    return sample


class ServeEngine:
    """Continuous-batching engine over one model family.

    ``prefill_mode``: 'batched' (whole prompts, one dispatch — the fast
    path) or 'loop' (token-at-a-time reference, the pre-§9 serve loop,
    kept as the benchmark baseline).  ``use_kernel`` routes GQA prefill
    attention through the Pallas flash kernel (interpret-mode on CPU)."""

    def __init__(self, arch: str, *, slots: int = 4, max_seq: int = 128,
                 smoke: bool = True, seed: int = 0, step_dt: float = 1.0,
                 prefill_mode: str = "batched", use_kernel: bool = False,
                 params=None, temperature: float = 0.0, top_p: float = 1.0,
                 sample_seed: Optional[int] = None, tracer=None, bus=None):
        from repro.serve.cache import SlotKVCache
        if prefill_mode not in ("batched", "loop"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        self.cfg = C.smoke(arch) if smoke else C.get(arch)
        self.ops = get_ops(self.cfg)
        if self.ops.decode is None or self.ops.prefill is None:
            raise ValueError(f"{arch} ({self.cfg.family}) is not servable")
        self.params = (params if params is not None
                       else self.ops.init(jax.random.key(seed)))
        self.kv = SlotKVCache(self.ops, slots, max_seq)
        self.prefill_mode = prefill_mode
        self.use_kernel = use_kernel
        self.step_dt = step_dt
        self.clock = 0.0
        self.step_idx = 0
        self.pending: list = []              # sorted by arrival
        self.active: dict = {}               # slot -> state dict
        self.counters = {"prefill_dispatch": 0, "decode_dispatch": 0,
                         "prefill_tokens": 0, "decode_tokens": 0}
        self.last_tok = np.zeros((slots, 1), np.int32)
        self.slot_rid = np.zeros((slots,), np.int32)
        self._prefill_jit: dict = {}
        self.temperature = float(temperature)
        self.top_p = float(top_p)
        self._sampler = _make_sampler(self.temperature, self.top_p)
        self._sample_key = jax.random.key(
            seed if sample_seed is None else sample_seed)
        self.tracer = tracer
        self.bus = bus
        self._submit_us: dict = {}           # rid -> submit time (trace µs)
        self._submit_t: dict = {}            # rid -> submit time.monotonic()
        vocab = self.cfg.vocab_size
        sampler, skey = self._sampler, self._sample_key

        def _decode(params, cache, toks, cursors, rids, poss):
            logits, cache = self.ops.decode(params, cache, toks, cursors)
            lg = logits[:, -1, :vocab]
            nxt = (jnp.argmax(lg, axis=-1) if sampler is None
                   else sampler(skey, rids, poss, lg))
            return nxt.astype(jnp.int32)[:, None], cache

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        # single-row position-0 sampler for the loop-mode reference prefill
        # (parity with the batched path's fused first-token sampling)
        if sampler is not None:
            self._sample1 = jax.jit(lambda rid, lg: sampler(
                skey, rid[None], jnp.zeros((1,), jnp.int32), lg[None])[0])
        # token-at-a-time reference prefill step (cache_len as a traced
        # scalar so one program serves every position)
        self._decode_t1 = jax.jit(
            lambda p, c, t, cl: self.ops.decode(p, c, t, cl))

    # -- prefill ------------------------------------------------------------
    def _prefill_fn(self, A: int, T: int):
        key = (A, T)
        if key in self._prefill_jit:
            return self._prefill_jit[key]
        ops, vocab = self.ops, self.cfg.vocab_size
        sampler, skey = self._sampler, self._sample_key
        kw = ({"use_kernel": True} if self.use_kernel
              and self.cfg.family == "dense" else {})

        def fn(params, tokens, lengths, rids):
            sub = self.kv.zeros_like_sub(ops, A)
            logits, sub = ops.prefill(params, sub, tokens, lengths, 0, **kw)
            rows = jnp.arange(A)
            lg = logits[rows, lengths - 1, :vocab]
            nxt = (jnp.argmax(lg, axis=-1) if sampler is None
                   else sampler(skey, rids, jnp.zeros_like(rids), lg))
            return nxt.astype(jnp.int32)[:, None], sub

        self._prefill_jit[key] = jax.jit(fn)
        return self._prefill_jit[key]

    def _admit(self, reqs) -> None:
        tr, bus = self.tracer, self.bus
        slots = self.kv.alloc(len(reqs))
        lens = np.array([len(r.tokens) for r in reqs], np.int32)
        rids = np.array([r.rid for r in reqs], np.int32)
        ctx = (tr.span("prefill", thread="engine", cat="serve",
                       batch=len(reqs), tokens=int(lens.sum()),
                       mode=self.prefill_mode)
               if tr is not None else contextlib.nullcontext())
        with ctx:
            if self.prefill_mode == "batched":
                T = _pow2_bucket(int(lens.max()))
                if not self.kv.stateful:
                    # bucket padding writes [0, T) into every row's KV slot,
                    # so the bucket itself must fit (admitted rows already do)
                    T = min(T, self.kv.max_seq)
                toks = np.zeros((len(reqs), T), np.int32)
                for i, r in enumerate(reqs):
                    toks[i, :lens[i]] = r.tokens
                first, sub = self._prefill_fn(len(reqs), T)(
                    self.params, jnp.asarray(toks), jnp.asarray(lens),
                    jnp.asarray(rids))
                self.counters["prefill_dispatch"] += 1
                self.kv.adopt(sub, slots, lens)
                first = np.asarray(first)
            else:                            # token-at-a-time reference loop
                first = np.zeros((len(reqs), 1), np.int32)
                sub_rows = []
                for i, r in enumerate(reqs):
                    logits = None
                    row = self.kv.zeros_like_sub(self.ops, 1)
                    for t in range(lens[i]):
                        tok = jnp.asarray(r.tokens[t:t + 1][None])
                        logits, row = self._decode_t1(
                            self.params, row, tok, jnp.int32(t))
                        self.counters["prefill_dispatch"] += 1
                    lg = logits[0, -1, :self.cfg.vocab_size]
                    first[i, 0] = (int(jnp.argmax(lg))
                                   if self._sampler is None
                                   else int(self._sample1(
                                       jnp.int32(r.rid), lg)))
                    sub_rows.append(row)
                sub = jax.tree.map(lambda *xs: jnp.concatenate(xs, 1),
                                   *sub_rows)
                self.kv.adopt(sub, slots, lens)
        self.counters["prefill_tokens"] += int(lens.sum())
        if bus is not None:
            bus.counter("serve/prefill_dispatch")
            bus.counter("serve/prefill_tokens", int(lens.sum()))
        now = time.monotonic()
        for i, (r, s) in enumerate(zip(reqs, slots)):
            self.last_tok[s, 0] = first[i, 0]
            self.slot_rid[s] = r.rid
            st = {"req": r, "out": [int(first[i, 0])],
                  "admit_step": self.step_idx, "t_first": now}
            if tr is not None:
                st["t0_us"] = self._submit_us.pop(r.rid, tr.now_us())
            if bus is not None:
                t_sub = self._submit_t.pop(r.rid, now)
                bus.observe("serve/ttft_s", now - t_sub)
            self.active[s] = st

    # -- scheduler ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.kv.validate_admit(len(req.tokens), req.max_new)
        if self.tracer is not None:
            self._submit_us[req.rid] = self.tracer.now_us()
        if self.bus is not None:
            self._submit_t[req.rid] = time.monotonic()
        self.pending.append(req)
        self.pending.sort(key=lambda r: (r.arrival, r.rid))

    def _evict_done(self) -> list:
        tr, bus = self.tracer, self.bus
        done = []
        for slot in sorted(self.active):
            st = self.active[slot]
            if len(st["out"]) >= st["req"].max_new:
                done.append(Finished(
                    rid=st["req"].rid, prompt_len=len(st["req"].tokens),
                    tokens=np.array(st["out"], np.int32),
                    admit_step=st["admit_step"], finish_step=self.step_idx))
                if tr is not None:
                    t1 = tr.now_us()
                    tr.complete(f"request/{st['req'].rid}",
                                st.get("t0_us", t1), t1,
                                thread=f"slot{slot}", cat="serve",
                                rid=st["req"].rid,
                                prompt_len=len(st["req"].tokens),
                                generated=len(st["out"]))
                if bus is not None:
                    n = len(st["out"])
                    if n > 1:
                        bus.observe("serve/tpot_s",
                                    (time.monotonic() - st["t_first"])
                                    / (n - 1))
                    bus.counter("serve/requests_done")
                del self.active[slot]
                self.kv.release(slot)
        return done

    def step(self) -> list:
        """One scheduler step: admit -> (maybe) decode -> evict.  Returns
        requests finished during this step."""
        if not self.active and self.pending:
            # idle engine: jump the virtual clock to the next arrival
            self.clock = max(self.clock, self.pending[0].arrival)
        grab = []
        while (self.pending and self.kv.free_count() > len(grab)
               and self.pending[0].arrival <= self.clock):
            grab.append(self.pending.pop(0))
        if grab:
            self._admit(grab)
        tr, bus = self.tracer, self.bus
        if bus is not None:
            bus.gauge("serve/slot_occupancy",
                      len(self.active) / self.kv.slots)
            bus.gauge("serve/queue_depth", len(self.pending))
        done = self._evict_done()            # max_new == 1 finishes here
        if not self.active:
            self.clock += self.step_dt
            self.step_idx += 1
            return done
        # the token being sampled is at position len(out): position 0 was
        # the prefill-fused first token, decode k samples position k
        poss = np.zeros((self.kv.slots,), np.int32)
        for slot, st in self.active.items():
            poss[slot] = len(st["out"])
        ctx = (tr.span("decode", thread="engine", cat="serve",
                       active=len(self.active), step=self.step_idx)
               if tr is not None else contextlib.nullcontext())
        with ctx:
            nxt, self.kv.tree = self._decode(
                self.params, self.kv.tree, jnp.asarray(self.last_tok),
                jnp.asarray(self.kv.cursors), jnp.asarray(self.slot_rid),
                jnp.asarray(poss))
            nxt = np.asarray(nxt)            # sync point (sampled on-device)
        self.counters["decode_dispatch"] += 1
        if bus is not None:
            bus.counter("serve/decode_dispatch")
            bus.counter("serve/decode_tokens", len(self.active))
        for slot, st in self.active.items():
            self.kv.cursors[slot] += 1
            st["out"].append(int(nxt[slot, 0]))
            self.last_tok[slot, 0] = nxt[slot, 0]
        self.counters["decode_tokens"] += len(self.active)
        done += self._evict_done()
        self.clock += self.step_dt
        self.step_idx += 1
        return done

    def run(self, trace=None) -> list:
        """Drive until every submitted/traced request finishes."""
        for r in (trace or []):
            self.submit(r)
        finished = []
        while self.pending or self.active:
            finished.extend(self.step())
        return sorted(finished, key=lambda f: f.rid)
