"""Continuous-batching inference service (DESIGN.md §9).

Slot-based paged KV cache + admission/eviction scheduler on top of the
ModelOps decode/prefill families.  See ``cache.SlotKVCache`` and
``engine.ServeEngine``.
"""
from repro.serve.cache import SlotKVCache
from repro.serve.engine import (Request, Finished, ServeEngine, RequestFeed,
                                poisson_trace)

__all__ = ["SlotKVCache", "Request", "Finished", "ServeEngine",
           "RequestFeed", "poisson_trace"]
