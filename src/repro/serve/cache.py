"""Slot-based paged KV/state cache for continuous batching (DESIGN.md §9).

The cache pytree is the family's own ``init_cache(slots, max_seq)`` tree —
every leaf has the slot (batch) axis at position 1, i.e. ``(L, slots, ...)``:
KV families carry ``(L, slots, max_seq, H, D)`` ring buffers, the stateful
family carries ``(L, slots, H, D, D)`` WKV state plus token-shift carries.
Compiled shapes therefore NEVER change as requests come and go: admission
scatters a freshly prefilled sub-cache into free slot rows, eviction just
returns the slot id to the free list (the row's stale contents are dead —
the next admission overwrites the whole row).

Host-side bookkeeping:
  * ``cursors`` — per-slot write cursor (absolute cache position of the
    next token).  Passed as the vector ``cache_len`` to decode, so one
    compiled dispatch steps slots sitting at different depths.
  * free list — allocation is lowest-slot-first and deterministic, so a
    replayed trace admits into the same slots.

Capacity contract: a KV slot holds ``max_seq`` positions; admission of a
request needs ``prompt_len + max_new <= max_seq`` (validated here with an
actionable error — the in-model ``_check_capacity`` guards the eager path,
this guards the jitted serving path whose cursors are traced).  The
stateful family has O(1) state and no sequence capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter(tree, sub, slot_ids):
    """Write sub-cache rows (slot axis 1) into the slot cache rows."""
    return jax.tree.map(
        lambda c, s: c.at[:, slot_ids].set(s.astype(c.dtype)), tree, sub)


class SlotKVCache:
    """Fixed-shape slot cache + free-slot map + per-slot write cursors."""

    def __init__(self, ops, slots: int, max_seq: int):
        self.slots = slots
        self.max_seq = max_seq
        self.tree = ops.init_cache(slots, max_seq)
        #: stateful families (rwkv) have no per-position axis to overflow
        self.stateful = "wkv" in self.tree
        self.cursors = np.zeros(slots, np.int32)
        self._free = sorted(range(slots), reverse=True)  # pop() -> lowest id

    # -- allocation ---------------------------------------------------------
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list:
        if n > len(self._free):
            raise RuntimeError(
                f"requested {n} slots but only {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def release(self, slot: int) -> None:
        self.cursors[slot] = 0
        self._free.append(slot)
        self._free.sort(reverse=True)

    # -- capacity -----------------------------------------------------------
    def validate_admit(self, prompt_len: int, max_new: int) -> None:
        """Reject a request that cannot fit: prompt + generated tokens must
        stay inside the slot's ``max_seq`` positions (KV families)."""
        if self.stateful:
            return
        need = prompt_len + max_new
        if need > self.max_seq:
            raise ValueError(
                f"request needs {need} cache positions (prompt={prompt_len} "
                f"+ max_new={max_new}) but slots hold max_seq={self.max_seq}; "
                f"raise ServeEngine(max_seq=...) or shorten the request")

    # -- adoption -----------------------------------------------------------
    def adopt(self, sub_tree, slot_ids, lengths) -> None:
        """Scatter a prefilled sub-cache (slot axis 1, rows parallel to
        ``slot_ids``) into the slot cache and start the write cursors at
        each row's true prompt length."""
        ids = jnp.asarray(np.asarray(slot_ids, np.int32))
        self.tree = _scatter(self.tree, sub_tree, ids)
        for s, ln in zip(slot_ids, np.asarray(lengths)):
            self.cursors[s] = int(ln)

    def zeros_like_sub(self, ops, n_rows: int):
        """A fresh all-zero sub-cache for ``n_rows`` prefill rows."""
        return jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype),
                            ops.abstract_cache(n_rows, self.max_seq))
