"""Mamba2 (SSD) block + Zamba2-style hybrid stack.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk linear state scan) — O(T * Q) work, linear in sequence length,
which is what makes the hybrid archs eligible for the long_500k cell.
Decode is a plain recurrent state update.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import layers as L
from repro.models.lm import _attn_params, _gqa_attention, _mlp_params
from repro.train.sharding import constrain

# SSD chunk length: the intra-chunk decay tensor is (B, T/Q, Q, Q, H) f32,
# i.e. linear in Q - 64 keeps it ~0.5GB/layer-transient at train_4k scale.
CHUNK = 64


def mamba_params(cfg: ArchConfig, f, shape0=()):
    d = cfg.d_model
    din = d * cfg.ssm_expand
    N = cfg.ssm_state
    H = max(din // 64, 1)  # ssm heads (headdim 64)
    ax = (None,) * len(shape0)
    return {
        # in_proj -> [x, z(gate), B, C, dt]
        "w_in": f.array(shape0 + (d, 2 * din + 2 * N + H), ax + ("fsdp", "tp")),
        "conv_w": f.array(shape0 + (cfg.ssm_conv, din), ax + (None, "tp")),
        "A_log": f.array(shape0 + (H,), None, mode="zeros"),
        "D": f.array(shape0 + (H,), None, mode="ones"),
        "dt_bias": f.array(shape0 + (H,), None, mode="zeros"),
        "w_out": f.array(shape0 + (din, d), ax + ("tp", "fsdp")),
        "ln": f.array(shape0 + (d,), None, mode="ones"),
    }


def _split_in(p, x, cfg):
    d = cfg.d_model
    din = d * cfg.ssm_expand
    N = cfg.ssm_state
    H = max(din // 64, 1)
    proj = x @ p["w_in"]
    xs, z, B_, C_, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    return xs, z, B_, C_, dt, din, N, H


def _causal_conv(xs, conv_w, state=None):
    """Depthwise causal conv.  xs: (B,T,din); conv_w: (K,din)."""
    K = conv_w.shape[0]
    if state is None:
        pad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    else:  # decode: state (B,K-1,din)
        pad = jnp.concatenate([state, xs], axis=1)
        new_state = pad[:, -(K - 1):]
    out = sum(pad[:, i:i + xs.shape[1]] * conv_w[i] for i in range(K))
    return jax.nn.silu(out.astype(jnp.float32)).astype(xs.dtype), new_state


HEAD_BLOCK = 16


def ssd_chunked(xh, dt, A, B_, C_, D):
    """Chunked SSD.  xh: (B,T,H,P), dt: (B,T,H), A: (H,) (negative),
    B_, C_: (B,T,N).  Returns (B,T,H,P).

    Heads are processed in blocks of HEAD_BLOCK: the intra-chunk decay /
    score tensors are (B, T/Q, Q, Q, h) f32 - blocking h bounds the
    transient (python loop, so dry-run cost accounting stays honest)."""
    H_all = xh.shape[2]
    if H_all > HEAD_BLOCK:
        outs = []
        for h0 in range(0, H_all, HEAD_BLOCK):
            sl = slice(h0, h0 + HEAD_BLOCK)
            outs.append(_ssd_chunked_hblock(
                xh[:, :, sl], dt[:, :, sl], A[sl], B_, C_, D[sl]))
        return jnp.concatenate(outs, axis=2)
    return _ssd_chunked_hblock(xh, dt, A, B_, C_, D)


def _ssd_chunked_hblock(xh, dt, A, B_, C_, D):
    Bsz, T, H, Pd = xh.shape
    N = B_.shape[-1]
    Q = min(CHUNK, T)
    nC = T // Q
    f32 = jnp.float32

    dt = jax.nn.softplus(dt.astype(f32))                 # (B,T,H)
    dA = dt * A.astype(f32)                              # log-decay per step
    x_dt = xh.astype(f32) * dt[..., None]

    # reshape into chunks
    def ck(t):
        return t.reshape(t.shape[0], nC, Q, *t.shape[2:])
    xc, dAc, Bc, Cc = ck(x_dt), ck(dA), ck(B_.astype(f32)), ck(C_.astype(f32))

    seg = jnp.cumsum(dAc, axis=2)                        # (B,nC,Q,H)
    # intra-chunk: scores[i,j] = C_i . B_j * exp(seg_i - seg_j), j<=i
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (B,nC,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, -jnp.inf)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    M = cb[..., None] * jnp.exp(decay)                   # (B,nC,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk states: S_c = sum_j exp(seg_last - seg_j) B_j x_j^T
    last = seg[:, :, -1:, :]                             # (B,nC,1,H)
    w = jnp.exp(last - seg)                              # (B,nC,Q,H)
    S_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, w, xc)

    # inter-chunk scan over nC states
    chunk_decay = jnp.exp(last[:, :, 0, :])              # (B,nC,H)

    def scan_body(S_prev, inp):
        dec, Sc = inp
        S = S_prev * dec[..., None, None] + Sc
        return S, S_prev
    S0 = jnp.zeros((Bsz, H, N, Pd), f32)
    _, S_prevs = jax.lax.scan(
        scan_body, S0,
        (chunk_decay.swapaxes(0, 1), S_c.swapaxes(0, 1)))
    S_prevs = S_prevs.swapaxes(0, 1)                     # (B,nC,H,N,P)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cc, jnp.exp(seg), S_prevs)
    y = (y_intra + y_inter).reshape(Bsz, T, H, Pd)
    y = y + xh.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(xh.dtype)


def mamba_block(p, x, cfg: ArchConfig, ssm_state=None, conv_state=None):
    """Returns (y, new_ssm_state, new_conv_state)."""
    B, T, d = x.shape
    xs, z, B_, C_, dt, din, N, H = _split_in(p, x, cfg)
    Pd = din // H
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if ssm_state is None:  # train / prefill
        xs, _ = _causal_conv(xs, p["conv_w"])
        xh = xs.reshape(B, T, H, Pd)
        y = ssd_chunked(xh, dt + p["dt_bias"], A, B_, C_, p["D"])
        new_ssm, new_conv = None, None
    else:  # decode (T == 1)
        xs, new_conv = _causal_conv(xs, p["conv_w"], conv_state)
        xh = xs.reshape(B, T, H, Pd)[:, 0]               # (B,H,P)
        dtv = jax.nn.softplus((dt + p["dt_bias"])[:, 0].astype(jnp.float32))
        dA = jnp.exp(dtv * A)                            # (B,H)
        Bv, Cv = B_[:, 0].astype(jnp.float32), C_[:, 0].astype(jnp.float32)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dtv, Bv, xh.astype(jnp.float32))
        new_ssm = ssm_state * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cv, new_ssm)
        y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
        y = y.reshape(B, 1, din).astype(x.dtype)
        xh = None
    if ssm_state is None:
        y = y.reshape(B, T, din)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ p["w_out"], new_ssm, new_conv


# ---------------------------------------------------------------------------
# Zamba2-style hybrid stack: mamba blocks + ONE shared attention block
# inserted every cfg.attn_every layers (attention weights reused each time).
# ---------------------------------------------------------------------------
def build_params(cfg: ArchConfig, f):
    Vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": f.array((Vp, d), ("tp", "fsdp"), scale=0.02),
        "out_embed": f.array((Vp, d), ("tp", "fsdp"), scale=0.02),
        "final_norm": f.array((d,), None, mode="ones"),
        "layers": mamba_params(cfg, f, (cfg.n_layers,)),
        "shared_attn": {
            "ln": f.array((d,), None, mode="ones"),
            **_attn_params(cfg, f),
        },
        "shared_mlp": {
            "ln": f.array((d,), None, mode="ones"),
            **_mlp_params(cfg, f),
        },
    }
    return params


def _attn_sites(cfg: ArchConfig):
    return set(range(cfg.attn_every - 1, cfg.n_layers, cfg.attn_every))


def forward(params, tokens, cfg: ArchConfig, patch_embeds=None,
            return_hidden: bool = False):
    """Hybrid stack.  The heterogeneous interleave (attn_every-1 mamba
    blocks + one shared-attention block) is scanned over *groups*, so the
    saved backward residuals are one carry per group rather than one per
    layer — this is what keeps the 38-layer train_4k cell inside HBM."""
    del patch_embeds
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]
    sites = _attn_sites(cfg)

    def mamba_f(lp, h):
        y, _, _ = mamba_block(lp, L.rms_norm(h, lp["ln"]), cfg)
        return h + y

    def shared_f(h):
        sa = params["shared_attn"]
        a, _ = _gqa_attention(sa, L.rms_norm(h, sa["ln"]), cfg, positions)
        h = h + a
        sm = params["shared_mlp"]
        h = h + L.swiglu(L.rms_norm(h, sm["ln"]), sm["w_gate"],
                         sm["w_up"], sm["w_down"])
        return constrain(h, "dp", "sp", None)

    P = max(cfg.attn_every, 1)
    G = cfg.n_layers // P

    def group_f(gp, h):
        for i in range(P):
            lp = jax.tree.map(lambda a, i=i: a[i], gp)
            h = mamba_f(lp, h)
        return shared_f(h)

    if cfg.remat:
        group_f = jax.checkpoint(group_f)
        mamba_tail = jax.checkpoint(mamba_f)
    else:
        mamba_tail = mamba_f

    if cfg.scan_layers and G > 0:
        grouped = jax.tree.map(
            lambda a: a[:G * P].reshape((G, P) + a.shape[1:]),
            params["layers"])

        def body(h, gp):
            return group_f(gp, h), None
        x, _ = jax.lax.scan(body, x, grouped)
        tail = range(G * P, cfg.n_layers)
    else:
        tail = range(cfg.n_layers)

    for i in tail:
        lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        x = mamba_tail(lp, x)
        if i in sites:
            x = shared_f(x)
        x = constrain(x, "dp", "sp", None)
    x = L.rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = jnp.einsum("btd,vd->btv", x, params["out_embed"])
    return constrain(logits, "dp", "sp", None), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ArchConfig):
    x, aux = forward(params, batch["tokens"], cfg, return_hidden=True)
    ce = L.fused_ce(x, params["out_embed"], batch["labels"], cfg.vocab_size)
    return ce, {"ce": ce, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, f):
    d = cfg.d_model
    din = d * cfg.ssm_expand
    N = cfg.ssm_state
    H = max(din // 64, 1)
    n_attn = len(_attn_sites(cfg))
    return {
        "ssm": f.array((cfg.n_layers, batch, H, N, din // H),
                       (None, "dp", None, None, None), mode="zeros"),
        "conv": f.array((cfg.n_layers, batch, cfg.ssm_conv - 1, din),
                        (None, "dp", None, "tp"), mode="zeros"),
        # shared-attention KV caches (one per attention site)
        "k": f.array((n_attn, batch, max_seq, cfg.n_kv_heads, cfg.d_head),
                     (None, "dp", "sp", None, None), mode="zeros"),
        "v": f.array((n_attn, batch, max_seq, cfg.n_kv_heads, cfg.d_head),
                     (None, "dp", "sp", None, None), mode="zeros"),
    }


def decode_step(params, cache, tokens, cache_len, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)
    positions = jnp.full((1, 1), cache_len, jnp.int32)
    sites = sorted(_attn_sites(cfg))
    new_ssm, new_conv, new_k, new_v = [], [], [], []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[i], params["layers"])
        y, s, c = mamba_block(lp, L.rms_norm(x, lp["ln"]), cfg,
                              cache["ssm"][i], cache["conv"][i])
        x = x + y
        new_ssm.append(s); new_conv.append(c)
        if i in sites:
            j = sites.index(i)
            sa = params["shared_attn"]
            a, (nk, nv) = _gqa_attention(sa, L.rms_norm(x, sa["ln"]), cfg,
                                         positions,
                                         (cache["k"][j], cache["v"][j]),
                                         cache_len)
            x = x + a
            sm = params["shared_mlp"]
            x = x + L.swiglu(L.rms_norm(x, sm["ln"]), sm["w_gate"],
                             sm["w_up"], sm["w_down"])
            new_k.append(nk); new_v.append(nv)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", x, params["out_embed"])
    logits = constrain(logits, "dp", "sp", None)
    return logits, {"ssm": jnp.stack(new_ssm), "conv": jnp.stack(new_conv),
                    "k": jnp.stack(new_k), "v": jnp.stack(new_v)}
