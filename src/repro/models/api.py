"""Unified model API: dispatches on ``cfg.family``.

    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))          # real arrays
    specs  = ops.param_specs()                     # logical PartitionSpec tree
    loss, metrics = ops.loss(params, batch)
    spec   = ops.bucket_spec()                     # ordered ParamBuckets
    loss, metrics, grads = ops.loss_and_grads(params, batch)
    loss, metrics, new_params, grads = ops.loss_and_grads(
        params, batch, tape=on_bucket)             # reverse-production tape
    cache  = ops.init_cache(batch_size, max_seq)   # decode families
    logits, cache = ops.decode(params, cache, tokens, cache_len)
    logits, cache = ops.prefill(params, cache, tokens, lengths, cache_len)

ParamBuckets (DESIGN.md §6): ``bucket_spec()`` partitions the param tree
into ordered, disjoint per-layer buckets — the granularity at which the
sync engine exchanges gradients, compression slices its error-feedback
residual, and optimizers slice their state.  ``loss_and_grads``'s tape mode
calls ``tape(bucket, params_b, grads_b) -> new_params_b | None`` once per
bucket in **reverse-production order**: the CNN family chains each call to
that layer's VJP gradient production (the paper's §3 per-layer non-instant
update); every other family computes the whole gradient once and walks the
buckets in reverse order (same exchange/update granularity, coarser
production chaining — their scanned layer stacks are single leaves).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig, ParamBucket
from repro.models import layers as L


@dataclasses.dataclass
class ModelOps:
    cfg: ArchConfig
    init: Callable
    param_specs: Callable
    abstract_params: Callable
    loss: Callable
    bucket_spec: Callable = None
    loss_and_grads: Callable = None
    init_cache: Optional[Callable] = None
    abstract_cache: Optional[Callable] = None
    cache_specs: Optional[Callable] = None
    decode: Optional[Callable] = None
    #: batched prefill (DESIGN.md §9): whole right-padded prompts in one
    #: dispatch.  ``prefill(params, cache, tokens, lengths, cache_len)`` —
    #: ``tokens`` (B, T), ``lengths`` (B,) true prompt lengths; row i's
    #: next-token logits live at position lengths[i]-1.
    prefill: Optional[Callable] = None
    forward: Optional[Callable] = None
    #: worker-mesh interleaved tape (DESIGN.md §8), families that have one:
    #: ``shard_bucket_grads(params, shards, on_bucket) -> (losses, metrics,
    #: grads)`` over a stacked (s, b, ...) micro-shard batch, firing
    #: ``on_bucket(bucket, grads_b_stacked) -> token | None`` the moment
    #: each layer's stacked gradient is produced during backprop.
    shard_bucket_grads: Optional[Callable] = None


def _mod(cfg: ArchConfig):
    if cfg.family in ("dense", "mla", "moe", "vlm"):
        from repro.models import lm
        return lm
    if cfg.family == "hybrid":
        from repro.models import mamba2
        return mamba2
    if cfg.family == "ssm":
        from repro.models import rwkv6
        return rwkv6
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec
    if cfg.family == "cnn":
        from repro.models import cnn
        return cnn
    raise ValueError(cfg.family)


def default_bucket_spec(abstract_params: dict) -> tuple:
    """Fallback ParamBuckets: one bucket per top-level param-tree key, in
    the model's construction order (an exact disjoint cover by
    construction)."""
    return tuple(ParamBucket(name=k, keys=(k,), index=i)
                 for i, k in enumerate(abstract_params))


def validate_bucket_spec(spec, abstract_params: dict) -> None:
    """Raise unless ``spec`` is an ordered exact disjoint cover of the
    param tree's top-level keys."""
    seen: list = []
    for b in spec:
        for k in b.keys:
            if k in seen:
                raise ValueError(
                    f"bucket {b.name!r} overlaps: key {k!r} already owned")
            if k not in abstract_params:
                raise ValueError(
                    f"bucket {b.name!r} names unknown param key {k!r}")
            seen.append(k)
    missing = set(abstract_params) - set(seen)
    if missing:
        raise ValueError(
            f"bucket_spec misses param keys {sorted(missing)}: buckets must "
            f"exactly cover the param tree")
    if [b.index for b in spec] != list(range(len(spec))):
        raise ValueError("bucket indices must be 0..n-1 in production order")


def get_ops(cfg: ArchConfig) -> ModelOps:
    mod = _mod(cfg)
    dtype = jnp.dtype(cfg.param_dtype)

    def init(key):
        return mod.build_params(cfg, L.InitFactory(key, dtype))

    def param_specs():
        return mod.build_params(cfg, L.SpecFactory())

    def abstract_params():
        return mod.build_params(cfg, L.ShapeFactory(dtype))

    def bucket_spec():
        if hasattr(mod, "bucket_spec"):
            return mod.bucket_spec(cfg)
        return default_bucket_spec(abstract_params())

    def loss_and_grads(params, batch, tape=None):
        """(loss, metrics, grads) — or, with ``tape``, the reverse-
        production bucket walk: ``tape(bucket, params_b, grads_b) ->
        new_params_b | None`` and a 4-tuple return (loss, metrics,
        new_params, grads).  CNN routes the tape through the per-layer VJP
        walk so each bucket's call is chained to that layer's gradient
        production."""
        if tape is not None and hasattr(mod, "loss_and_bucket_grads"):
            return mod.loss_and_bucket_grads(params, batch, cfg, tape)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p, b: mod.loss_fn(p, b, cfg), has_aux=True)(params, batch)
        if tape is None:
            return loss, metrics, grads
        new_params = dict(params)
        for bucket in reversed(bucket_spec()):
            out = tape(bucket, bucket.view(params), bucket.view(grads))
            if out is not None:
                new_params.update(out)
        return loss, metrics, new_params, grads

    ops = ModelOps(
        cfg=cfg, init=init, param_specs=param_specs,
        abstract_params=abstract_params,
        loss=lambda params, batch: mod.loss_fn(params, batch, cfg),
        bucket_spec=bucket_spec, loss_and_grads=loss_and_grads,
        forward=getattr(mod, "forward", None) and (
            lambda params, *a, **k: mod.forward(params, *a, cfg=cfg, **k)
            if cfg.family != "cnn" else mod.forward(params, *a, cfg, **k)),
    )
    if hasattr(mod, "loss_and_shard_bucket_grads"):
        ops.shard_bucket_grads = (
            lambda params, shards, on_bucket:
            mod.loss_and_shard_bucket_grads(params, shards, cfg, on_bucket))
    if hasattr(mod, "init_cache"):
        cache_dtype = jnp.dtype("bfloat16")
        ops.init_cache = lambda b, s: mod.init_cache(
            cfg, b, s, L.InitFactory(jax.random.key(0), cache_dtype))
        ops.abstract_cache = lambda b, s: mod.init_cache(
            cfg, b, s, L.ShapeFactory(cache_dtype))
        ops.cache_specs = lambda b, s: mod.init_cache(
            cfg, b, s, L.SpecFactory())
        ops.decode = (
            lambda params, cache, tokens, cache_len, **kw: mod.decode_step(
                params, cache, tokens, cache_len, cfg, **kw))
    if hasattr(mod, "prefill_step"):
        ops.prefill = (
            lambda params, cache, tokens, lengths, cache_len, **kw:
            mod.prefill_step(params, cache, tokens, lengths, cache_len,
                             cfg, **kw))
    return ops
