"""Unified model API: dispatches on ``cfg.family``.

    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))          # real arrays
    specs  = ops.param_specs()                     # logical PartitionSpec tree
    loss, metrics = ops.loss(params, batch)
    cache  = ops.init_cache(batch_size, max_seq)   # decode families
    logits, cache = ops.decode(params, cache, tokens, cache_len)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import layers as L


@dataclasses.dataclass
class ModelOps:
    cfg: ArchConfig
    init: Callable
    param_specs: Callable
    abstract_params: Callable
    loss: Callable
    init_cache: Optional[Callable] = None
    abstract_cache: Optional[Callable] = None
    cache_specs: Optional[Callable] = None
    decode: Optional[Callable] = None
    forward: Optional[Callable] = None


def _mod(cfg: ArchConfig):
    if cfg.family in ("dense", "mla", "moe", "vlm"):
        from repro.models import lm
        return lm
    if cfg.family == "hybrid":
        from repro.models import mamba2
        return mamba2
    if cfg.family == "ssm":
        from repro.models import rwkv6
        return rwkv6
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec
    if cfg.family == "cnn":
        from repro.models import cnn
        return cnn
    raise ValueError(cfg.family)


def get_ops(cfg: ArchConfig) -> ModelOps:
    mod = _mod(cfg)
    dtype = jnp.dtype(cfg.param_dtype)

    def init(key):
        return mod.build_params(cfg, L.InitFactory(key, dtype))

    def param_specs():
        return mod.build_params(cfg, L.SpecFactory())

    def abstract_params():
        return mod.build_params(cfg, L.ShapeFactory(dtype))

    ops = ModelOps(
        cfg=cfg, init=init, param_specs=param_specs,
        abstract_params=abstract_params,
        loss=lambda params, batch: mod.loss_fn(params, batch, cfg),
        forward=getattr(mod, "forward", None) and (
            lambda params, *a, **k: mod.forward(params, *a, cfg=cfg, **k)
            if cfg.family != "cnn" else mod.forward(params, *a, cfg, **k)),
    )
    if hasattr(mod, "init_cache"):
        cache_dtype = jnp.dtype("bfloat16")
        ops.init_cache = lambda b, s: mod.init_cache(
            cfg, b, s, L.InitFactory(jax.random.key(0), cache_dtype))
        ops.abstract_cache = lambda b, s: mod.init_cache(
            cfg, b, s, L.ShapeFactory(cache_dtype))
        ops.cache_specs = lambda b, s: mod.init_cache(
            cfg, b, s, L.SpecFactory())
        ops.decode = lambda params, cache, tokens, cache_len: mod.decode_step(
            params, cache, tokens, cache_len, cfg)
    return ops
