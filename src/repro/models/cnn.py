"""The paper's CNNs (Table 2): conv/max-pool/fc stacks for 29x29 MNIST.

Faithful to Cireşan-style nets used in the paper: valid convolutions,
max-pooling, tanh hidden activations, softmax output, MSE-free CE loss,
SGD with the paper's decay schedule (eta0=0.001, x0.9 per epoch).

``use_kernel=True`` (argument, or ``cfg.use_kernel`` when the argument is
left as None) routes the WHOLE hot path through the fused, autotuned
Pallas TPU kernels (`repro.kernels.ops`) — the SIMD-vectorisation
analogue (DESIGN.md §2, §Kernels): one fused conv+bias+tanh launch
forward and one fused dx+dw+db launch backward per conv layer, Pallas
max-pool both ways, one fused matmul+bias(+tanh) launch per FC layer
each way, and a fused softmax-cross-entropy kernel whose backward reuses
the saved dlogits (zero extra launches).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig, ParamBucket


def _trace_shapes(cfg: ArchConfig):
    """Yield (kind, spec, h, c_in, c_out) per layer; h = output spatial."""
    h = cfg.cnn_input[0]
    c = 1
    out = []
    for spec in cfg.cnn_layers:
        if spec[0] == "conv":
            _, maps, k = spec
            h = h - k + 1
            out.append(("conv", k, h, c, maps))
            c = maps
        elif spec[0] == "pool":
            _, k = spec
            h = h // k
            out.append(("pool", k, h, c, c))
        else:
            _, n = spec
            out.append(("fc", None, n, c * h * h, n))
            h, c = 1, n
    out.append(("fc", None, cfg.n_classes, c * h * h if h > 1 else c,
                cfg.n_classes))
    return out


def param_count(cfg: ArchConfig) -> int:
    n = 0
    for kind, k, _, cin, cout in _trace_shapes(cfg):
        if kind == "conv":
            n += k * k * cin * cout + cout
        elif kind == "fc":
            n += cin * cout + cout
    return n


def build_params(cfg: ArchConfig, f):
    params = {}
    for i, (kind, k, _, cin, cout) in enumerate(_trace_shapes(cfg)):
        if kind == "conv":
            params[f"conv{i}"] = {
                "w": f.array((k, k, cin, cout), None,
                             scale=1.0 / math.sqrt(k * k * cin)),
                "b": f.array((cout,), None, mode="zeros"),
            }
        elif kind == "fc":
            params[f"fc{i}"] = {
                "w": f.array((cin, cout), ("fsdp", None),
                             scale=1.0 / math.sqrt(cin)),
                "b": f.array((cout,), None, mode="zeros"),
            }
    return params


def bucket_spec(cfg: ArchConfig) -> tuple:
    """ParamBuckets (DESIGN.md §6): one bucket per parameterised Table-2
    layer, in forward (production) order — pool layers carry no params and
    therefore no bucket.  The per-layer VJP tape yields these buckets at
    ``index`` descending (reverse-production order, the paper's §3 walk)."""
    buckets = []
    for i, (kind, *_rest) in enumerate(_trace_shapes(cfg)):
        if kind in ("conv", "fc"):
            name = f"{kind}{i}"
            buckets.append(ParamBucket(name=name, keys=(name,),
                                       index=len(buckets)))
    return tuple(buckets)


def _use_kernel(cfg: ArchConfig, use_kernel):
    return cfg.use_kernel if use_kernel is None else use_kernel


def forward(params, images, cfg: ArchConfig, use_kernel: bool | None = None):
    """images: (B, H, W, 1) float32 in [0,1].  Returns (B, n_classes) logits."""
    x = images
    uk = _use_kernel(cfg, use_kernel)
    if uk:
        from repro.kernels import ops as kops
    shapes = _trace_shapes(cfg)
    for i, (kind, k, _, cin, cout) in enumerate(shapes):
        if kind == "conv":
            p = params[f"conv{i}"]
            if uk:
                x = kops.conv2d_bias_tanh(x, p["w"], p["b"])
            else:
                x = jnp.tanh(jax.lax.conv_general_dilated(
                    x, p["w"], (1, 1), "VALID",
                    dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"])
        elif kind == "pool":
            if k > 1:
                if uk:
                    x = kops.maxpool2d(x, k)
                else:
                    x = jax.lax.reduce_window(
                        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1),
                        "VALID")
        else:
            p = params[f"fc{i}"]
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            last = i == len(shapes) - 1
            if uk:
                x = (kops.fc_bias(x, p["w"], p["b"]) if last
                     else kops.fc_bias_tanh(x, p["w"], p["b"]))
            else:
                x = x @ p["w"] + p["b"]
                if not last:
                    x = jnp.tanh(x)
    return x


def _layer_fns(cfg: ArchConfig, uk: bool):
    """One closure per Table-2 layer, in forward order: ``(name, fn)`` where
    ``fn(p, x)`` (params-less layers: ``fn(x)``, name None) runs that layer
    through the XLA or Pallas-kernel path.  Shared by the layerwise walk so
    both paths stay byte-compatible with ``forward``."""
    if uk:
        from repro.kernels import ops as kops
    shapes = _trace_shapes(cfg)
    out = []
    for i, (kind, k, _, cin, cout) in enumerate(shapes):
        if kind == "conv":
            if uk:
                fn = lambda p, x: kops.conv2d_bias_tanh(x, p["w"], p["b"])
            else:
                fn = lambda p, x: jnp.tanh(jax.lax.conv_general_dilated(
                    x, p["w"], (1, 1), "VALID",
                    dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"])
            out.append((f"conv{i}", fn))
        elif kind == "pool":
            if k > 1:
                if uk:
                    fn = lambda x, k=k: kops.maxpool2d(x, k)
                else:
                    fn = lambda x, k=k: jax.lax.reduce_window(
                        x, -jnp.inf, jax.lax.max, (1, k, k, 1),
                        (1, k, k, 1), "VALID")
                out.append((None, fn))
        else:
            last = i == len(shapes) - 1

            def fn(p, x, last=last):
                if x.ndim > 2:
                    x = x.reshape(x.shape[0], -1)
                if uk:
                    return (kops.fc_bias(x, p["w"], p["b"]) if last
                            else kops.fc_bias_tanh(x, p["w"], p["b"]))
                x = x @ p["w"] + p["b"]
                return x if last else jnp.tanh(x)
            out.append((f"fc{i}", fn))
    return out


def _layer_bwd_fns(cfg: ArchConfig, uk: bool):
    """Saved-activation backward closure per layer, forward order (matching
    ``_layer_fns``): ``bwd(p, x, y, g) -> (dp, dx)`` for parameterised
    layers, ``bwd(x, y, g) -> dx`` for pool.  ``x``/``y`` are the layer's
    checkpointed input/output activations, so no closure re-runs the
    forward: the kernel path calls the fused backward kernels directly
    (``kernels/ops.py`` saved-activation entry points) and the XLA path
    applies the exact tanh VJP rule ``g * (1 - y*y)`` plus
    ``jax.linear_transpose`` of the linear conv/matmul — the same
    primitives ``jax.vjp`` would emit, minus the primal recompute."""
    if uk:
        from repro.kernels import ops as kops
    shapes = _trace_shapes(cfg)
    dn = ("NHWC", "HWIO", "NHWC")
    out = []
    for i, (kind, k, _, cin, cout) in enumerate(shapes):
        if kind == "conv":
            if uk:
                def bwd(p, x, y, g):
                    dx, dw, db = kops.conv2d_bias_tanh_bwd(
                        x, p["w"], p["b"], y, g)
                    return {"w": dw, "b": db}, dx
            else:
                def bwd(p, x, y, g):
                    g = g * (1.0 - y * y)
                    conv_x = lambda x_: jax.lax.conv_general_dilated(
                        x_, p["w"], (1, 1), "VALID", dimension_numbers=dn)
                    conv_w = lambda w_: jax.lax.conv_general_dilated(
                        x, w_, (1, 1), "VALID", dimension_numbers=dn)
                    (dx,) = jax.linear_transpose(conv_x, x)(g)
                    (dw,) = jax.linear_transpose(conv_w, p["w"])(g)
                    return ({"w": dw.astype(p["w"].dtype),
                             "b": g.sum((0, 1, 2)).astype(p["b"].dtype)},
                            dx.astype(x.dtype))
            out.append(bwd)
        elif kind == "pool":
            if k > 1:
                if uk:
                    bwd = lambda x, y, g, k=k: kops.maxpool2d_vjp_saved(
                        x, y, g, k)
                else:
                    def bwd(x, y, g, k=k):
                        pool = lambda x_: jax.lax.reduce_window(
                            x_, -jnp.inf, jax.lax.max, (1, k, k, 1),
                            (1, k, k, 1), "VALID")
                        _, vjp = jax.vjp(pool, x)
                        (dx,) = vjp(g)
                        return dx
                out.append(bwd)
        else:
            last = i == len(shapes) - 1

            def bwd(p, x, y, g, last=last):
                xf = x.reshape(x.shape[0], -1) if x.ndim > 2 else x
                if uk:
                    if last:
                        dxf, dw, db = kops.fc_bias_bwd(xf, p["w"], p["b"], g)
                    else:
                        dxf, dw, db = kops.fc_bias_tanh_bwd(
                            xf, p["w"], p["b"], y, g)
                else:
                    if not last:
                        g = g * (1.0 - y * y)
                    dw = (xf.T @ g).astype(p["w"].dtype)
                    db = g.sum(0).astype(p["b"].dtype)
                    dxf = (g @ p["w"].T).astype(x.dtype)
                return {"w": dw, "b": db}, dxf.reshape(x.shape)
            out.append(bwd)
    return out


def loss_and_bucket_grads(params, batch, cfg: ArchConfig, tape,
                          use_kernel: bool | None = None):
    """The paper's §3 update rule as a **bucket tape** (DESIGN.md §6):
    non-instant per-bucket weight updates DURING back-propagation.

    Forward runs at the incoming ``params`` recording a per-layer VJP tape;
    the backward walk then visits buckets in reverse-production order and,
    the moment bucket b's gradient is produced, calls
    ``tape(bucket, params_b, grads_b) -> new_params_b`` (``None`` leaves the
    bucket untouched) — so in the compiled graph each bucket's exchange +
    update is chained to that bucket's gradient production, not to a
    whole-tree barrier ("without significant delay").  The same walk drives
    the XLA and the fused Pallas-kernel paths (each layer closure carries
    its own custom-VJP kernels).

    Returns ``(loss, metrics, new_params, grads)`` with ``grads`` the fresh
    float32 per-bucket gradients (for the sync strategy's exchange).
    """
    uk = _use_kernel(cfg, use_kernel)
    x = batch["images"]
    labels = batch["labels"]
    buckets = {b.name: b for b in bucket_spec(cfg)}
    layer_tape = []
    for name, fn in _layer_fns(cfg, uk):
        if name is None:
            x, vjp = jax.vjp(fn, x)
        else:
            x, vjp = jax.vjp(fn, params[name], x)
        layer_tape.append((name, vjp))

    def loss_part(logits):
        logits = logits.astype(jnp.float32)
        if uk:
            from repro.kernels import ops as kops
            return jnp.mean(kops.softmax_xent(logits, labels))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - ll)

    loss, vjp_loss = jax.vjp(loss_part, x)
    logits32 = x.astype(jnp.float32)
    err = jnp.mean((jnp.argmax(logits32, -1) != labels).astype(jnp.float32))
    metrics = {"ce": loss, "error_rate": err,
               "aux": jnp.zeros((), jnp.float32)}

    (dy,) = vjp_loss(jnp.ones((), loss.dtype))
    new_params = dict(params)
    grads = {}
    for name, vjp in reversed(layer_tape):
        if name is None:
            (dy,) = vjp(dy)
            continue
        dp, dy = vjp(dy)
        dp = jax.tree.map(lambda t: t.astype(jnp.float32), dp)
        grads[name] = dp
        out = tape(buckets[name], {name: params[name]}, {name: dp})
        if out is not None:
            new_params.update(out)
    return loss, metrics, new_params, grads


def loss_and_shard_bucket_grads(params, shards, cfg: ArchConfig, on_bucket,
                                use_kernel: bool | None = None):
    """Worker-mesh flavour of the bucket tape (DESIGN.md §8): the per-layer
    backward walk over a stack of micro-shards, firing ``on_bucket`` the
    moment each layer's STACKED gradient exists.

    ``shards`` is the batch pytree with a leading ``(s, b, ...)`` micro-shard
    axis.  Output matches ``lax.map(value_and_grad(loss_fn))`` over that axis
    exactly — ``(losses (s,), metrics {(s,)}, grads {layer: (s, ...) f32})``
    — because every per-shard computation runs through the same per-shard
    ``lax.map`` bodies with the same layer closures (``_layer_fns``); only
    the *schedule* differs: the forward checkpoints each layer's stacked
    input AND output activations (outputs are free — layer i's output is
    layer i+1's input, already live), and the backward consumes the saved
    pair through ``_layer_bwd_fns`` — fused backward kernels fed the saved
    output directly on the kernel path, the exact tanh VJP rule plus
    ``jax.linear_transpose`` on the XLA path — so no layer's forward is
    re-run during the walk (the PR 7 tape re-linearised every layer with
    ``jax.vjp``, ~15 ms/step of recompute on the forced-host mesh) and
    ``on_bucket(bucket, {layer: dp_stacked})`` can issue that bucket's
    exchange collective while the remaining layers' backward is still to
    run.  ``on_bucket`` returns an ordering token (or None); the
    token is tied into the downstream cotangent WITHOUT changing its value
    (``core/chaos.py::delay_tie``), pinning the collective's issue point
    into the backward walk so XLA cannot sink it to the end of the step.
    """
    from repro.core.chaos import delay_tie
    uk = _use_kernel(cfg, use_kernel)
    buckets = {b.name: b for b in bucket_spec(cfg)}
    layers = _layer_fns(cfg, uk)
    labels = shards["labels"]

    xs = shards["images"]
    acts = [xs]  # acts[i] / acts[i+1] = layer i's stacked input / output
    for name, fn in layers:
        if name is None:
            xs = jax.lax.map(fn, xs)
        else:
            xs = jax.lax.map(lambda x, p=params[name], fn=fn: fn(p, x), xs)
        acts.append(xs)

    if uk:
        from repro.kernels import ops as kops

    def loss_and_dy(args):
        logits, lab = args

        def loss_part(lg):
            lg = lg.astype(jnp.float32)
            if uk:
                return jnp.mean(kops.softmax_xent(lg, lab))
            lse = jax.nn.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, lab[:, None], axis=-1)[:, 0]
            return jnp.mean(lse - ll)

        loss, vjp_loss = jax.vjp(loss_part, logits)
        (dy,) = vjp_loss(jnp.ones((), loss.dtype))
        lg32 = logits.astype(jnp.float32)
        err = jnp.mean((jnp.argmax(lg32, -1) != lab).astype(jnp.float32))
        return loss, err, dy

    losses, errs, dy = jax.lax.map(loss_and_dy, (xs, labels))
    metrics = {"ce": losses, "error_rate": errs,
               "aux": jnp.zeros_like(losses)}

    grads = {}
    bwds = _layer_bwd_fns(cfg, uk)
    for (name, _fn), bwd, x_in, y_out in zip(
            reversed(layers), reversed(bwds),
            reversed(acts[:-1]), reversed(acts[1:])):
        if name is None:
            dy = jax.lax.map(lambda a, bwd=bwd: bwd(*a), (x_in, y_out, dy))
            continue

        def bwd_layer(args, bwd=bwd, p=params[name]):
            x, y, g = args
            dp, dx = bwd(p, x, y, g)
            return jax.tree.map(lambda t: t.astype(jnp.float32), dp), dx

        dp, dy = jax.lax.map(bwd_layer, (x_in, y_out, dy))
        grads[name] = dp
        dy = delay_tie(dy, on_bucket(buckets[name], {name: dp}))
    return losses, metrics, grads


def loss_fn(params, batch, cfg: ArchConfig, use_kernel: bool | None = None):
    uk = _use_kernel(cfg, use_kernel)
    logits = forward(params, batch["images"], cfg, use_kernel=uk)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if uk:
        from repro.kernels import ops as kops
        loss = jnp.mean(kops.softmax_xent(logits, labels))
    else:
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(lse - ll)
    err = jnp.mean((jnp.argmax(logits, -1) != labels).astype(jnp.float32))
    return loss, {"ce": loss, "error_rate": err,
                  "aux": jnp.zeros((), jnp.float32)}
