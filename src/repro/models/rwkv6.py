"""RWKV-6 (Finch): attention-free LM with data-dependent decay.

Per head h with state S in R^{D x D}:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
where w_t = exp(-exp(wx_t)) is the data-dependent decay (token-shift + LoRA).

Training uses a chunked formulation (parallel within chunks of size Q,
sequential scan over T/Q chunks) — linear in T, so rwkv6 runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import layers as L
from repro.train.sharding import constrain

CHUNK = 64
LORA = 64


def build_params(cfg: ArchConfig, f):
    Vp, d = cfg.padded_vocab, cfg.d_model
    H = cfg.n_heads
    D = cfg.d_head
    Lr = LORA
    ax0 = (None,)
    lay = {
        "ln1": f.array((cfg.n_layers, d), None, mode="ones"),
        "ln2": f.array((cfg.n_layers, d), None, mode="ones"),
        # token-shift mixing coefficients
        "mu_r": f.array((cfg.n_layers, d), None, mode="ones"),
        "mu_k": f.array((cfg.n_layers, d), None, mode="ones"),
        "mu_v": f.array((cfg.n_layers, d), None, mode="ones"),
        "mu_w": f.array((cfg.n_layers, d), None, mode="ones"),
        "w_r": f.array((cfg.n_layers, d, H * D), ax0 + ("fsdp", "tp")),
        "w_k": f.array((cfg.n_layers, d, H * D), ax0 + ("fsdp", "tp")),
        "w_v": f.array((cfg.n_layers, d, H * D), ax0 + ("fsdp", "tp")),
        "w_o": f.array((cfg.n_layers, H * D, d), ax0 + ("tp", "fsdp")),
        # data-dependent decay LoRA: d -> Lr -> H*D
        "w_dec1": f.array((cfg.n_layers, d, Lr), ax0 + ("fsdp", None)),
        "w_dec2": f.array((cfg.n_layers, Lr, H * D), ax0 + (None, "tp")),
        "dec_bias": f.array((cfg.n_layers, H * D), None, mode="zeros"),
        "u": f.array((cfg.n_layers, H, D), None, mode="zeros"),
        "g_norm": f.array((cfg.n_layers, H * D), None, mode="ones"),
        # channel-mix FFN (relu^2)
        "fk": f.array((cfg.n_layers, d, cfg.d_ff), ax0 + ("fsdp", "tp")),
        "fv": f.array((cfg.n_layers, cfg.d_ff, d), ax0 + ("tp", "fsdp")),
        "fr": f.array((cfg.n_layers, d, d), ax0 + ("fsdp", None)),
        "mu_fk": f.array((cfg.n_layers, d), None, mode="ones"),
        "mu_fr": f.array((cfg.n_layers, d), None, mode="ones"),
    }
    return {
        "embed": f.array((Vp, d), ("tp", "fsdp"), scale=0.02),
        "out_embed": f.array((Vp, d), ("tp", "fsdp"), scale=0.02),
        "final_norm": f.array((d,), None, mode="ones"),
        "layers": lay,
    }


def _token_shift(x, prev=None):
    """Shift sequence right by one.  prev: (B,1,d) last token of prior state."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def wkv_chunked(r, k, v, w, u, initial_state=None):
    """Chunked WKV.  r,k,v: (B,T,H,D); w: (B,T,H,D) decay in (0,1];
    u: (H,D) bonus; initial_state: None or (B,H,D,D) carried WKV state
    (prefill of a continued sequence).  Returns (B,T,H,D), final_state
    (B,H,D,D).  T must be <= CHUNK or a multiple of CHUNK."""
    B, T, H, D = r.shape
    Q = min(CHUNK, T)
    nC = T // Q
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    logw = jnp.log(jnp.clip(w, 1e-12))                   # (B,T,H,D)

    def ck(t):
        return t.reshape(B, nC, Q, H, D)
    rc, kc, vc, lwc = ck(r), ck(k), ck(v), ck(logw)
    seg = jnp.cumsum(lwc, axis=2)                        # inclusive cumsum

    # intra-chunk:
    #   y_i += sum_{j<i} r_i . (prod_{j<m<i} w_m) k_j v_j + (u * k_i . r_i) v_i
    # contribution factor exp(seg_{i-1} - seg_j); decay logs are clamped in
    # _time_mix so exp(-seg) stays finite in f32 for Q=64 (see module doc).
    ri = rc * jnp.exp(seg - lwc)                         # exp(seg_{i-1})
    kj = kc * jnp.exp(-seg)                              # exp(-seg_j)
    att = jnp.einsum("bcihd,bcjhd->bchij", ri, kj)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    att = att * mask[None, None, None]
    y_intra = jnp.einsum("bchij,bcjhd->bcihd", att, vc)
    bonus = jnp.einsum("bcihd,hd,bcihd->bcih", rc, u.astype(f32), kc)
    y_intra = y_intra + bonus[..., None] * vc

    # chunk states: S_c = sum_j (prod_{m=j+1..Q-1} w_m) k_j^T v_j
    wj = jnp.exp(seg[:, :, -1:, :, :] - seg)
    S_c = jnp.einsum("bcjhd,bcjhe->bchde", kc * wj, vc)
    chunk_decay = jnp.exp(seg[:, :, -1])                 # (B,nC,H,D)

    def scan_body(S_prev, inp):
        dec, Sc = inp
        return S_prev * dec[..., None] + Sc, S_prev
    S0 = (jnp.zeros((B, H, D, D), f32) if initial_state is None
          else initial_state.astype(f32))
    S_last, S_prevs = jax.lax.scan(
        scan_body, S0, (chunk_decay.swapaxes(0, 1), S_c.swapaxes(0, 1)))
    S_prevs = S_prevs.swapaxes(0, 1)                     # (B,nC,H,D,D)

    # inter-chunk: y_i += (r_i * prod_{m=0..i-1} w_m) S_prev
    y_inter = jnp.einsum("bcihd,bchde->bcihe", ri, S_prevs)
    y = (y_intra + y_inter).reshape(B, T, H, D)
    return y, S_last


def _time_mix(lp, x, prev_tok, state, cfg, pad_mask=None):
    """RWKV6 time-mix.  state: None (train) or (B,H,D,D).  pad_mask (B,T)
    marks real tokens in a stateful T>1 prefill: padded positions are made
    state-neutral (w=1, k=0 => S_t = S_{t-1}) so right-padded prompts leave
    the exact same state as their unpadded tokens alone."""
    B, T, d = x.shape
    H, D = cfg.n_heads, cfg.d_head
    xs = _token_shift(x, prev_tok)
    def mix(mu):
        return x * mu + xs * (1 - mu)
    r = (mix(lp["mu_r"]) @ lp["w_r"]).reshape(B, T, H, D)
    k = (mix(lp["mu_k"]) @ lp["w_k"]).reshape(B, T, H, D)
    v = (mix(lp["mu_v"]) @ lp["w_v"]).reshape(B, T, H, D)
    dec = jax.nn.tanh(mix(lp["mu_w"]) @ lp["w_dec1"]) @ lp["w_dec2"]
    dec = dec + lp["dec_bias"]
    # clamp exp(dec) <= 1 so per-step log-decay >= -1; over a CHUNK of 64 the
    # rescaling factor exp(-seg) <= e^64 stays finite in float32.
    dec = jnp.clip(dec.astype(jnp.float32), None, 0.0)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, T, H, D)
    if state is None:
        y, S_last = wkv_chunked(r, k, v, w, lp["u"])
    elif T > 1:  # stateful batched prefill
        if pad_mask is not None:
            m = pad_mask[:, :, None, None]
            k = jnp.where(m, k, 0.0)
            w = jnp.where(m, w, 1.0)
        y, S_last = wkv_chunked(r, k, v, w, lp["u"],
                                initial_state=state.astype(jnp.float32))
    else:  # decode: T == 1
        r1, k1, v1, w1 = (t[:, 0].astype(jnp.float32) for t in (r, k, v, w))
        kv = jnp.einsum("bhd,bhe->bhde", k1, v1)
        y = jnp.einsum("bhd,bhde->bhe",
                       r1, state + lp["u"].astype(jnp.float32)[None, :, :, None] * kv)
        S_last = state * w1[..., None] + kv
        y = y[:, None]
    y = y.reshape(B, T, H * D)
    y = L.rms_norm(y, lp["g_norm"]).astype(x.dtype)
    return y @ lp["w_o"], S_last


def _channel_mix(lp, x, prev_tok):
    xs = _token_shift(x, prev_tok)
    xk = x * lp["mu_fk"] + xs * (1 - lp["mu_fk"])
    xr = x * lp["mu_fr"] + xs * (1 - lp["mu_fr"])
    h = jnp.square(jax.nn.relu(xk @ lp["fk"]))
    return jax.nn.sigmoid((xr @ lp["fr"]).astype(jnp.float32)).astype(x.dtype) * (h @ lp["fv"])


def _layer(lp, x, cfg, tm_prev=None, cm_prev=None, state=None):
    a, S = _time_mix(lp, L.rms_norm(x, lp["ln1"]), tm_prev, state, cfg)
    x = x + a
    x = x + _channel_mix(lp, L.rms_norm(x, lp["ln2"]), cm_prev)
    return constrain(x, "dp", "sp", None), S


def forward(params, tokens, cfg: ArchConfig, patch_embeds=None,
            return_hidden: bool = False):
    del patch_embeds
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)

    def body(h, lp):
        f = lambda lp_, h_: _layer(lp_, h_, cfg)[0]
        if cfg.remat:
            f = jax.checkpoint(f)
        return f(lp, h), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["layers"])
    else:
        f = lambda lp_, h_: _layer(lp_, h_, cfg)[0]
        if cfg.remat:
            f = jax.checkpoint(f)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
            x = f(lp, x)
    x = L.rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = jnp.einsum("btd,vd->btv", x, params["out_embed"])
    return constrain(logits, "dp", "sp", None), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ArchConfig):
    x, aux = forward(params, batch["tokens"], cfg, return_hidden=True)
    ce = L.fused_ce(x, params["out_embed"], batch["labels"], cfg.vocab_size)
    return ce, {"ce": ce, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, f):
    H, D, d = cfg.n_heads, cfg.d_head, cfg.d_model
    return {
        "wkv": f.array((cfg.n_layers, batch, H, D, D),
                       (None, "dp", None, None, None), mode="zeros"),
        "tm_x": f.array((cfg.n_layers, batch, 1, d),
                        (None, "dp", None, None), mode="zeros"),
        "cm_x": f.array((cfg.n_layers, batch, 1, d),
                        (None, "dp", None, None), mode="zeros"),
    }


def decode_step(params, cache, tokens, cache_len, cfg: ArchConfig):
    del cache_len
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)

    def body(h, packed):
        lp, wkv, tm_x, cm_x = packed
        h_in = h
        n1 = L.rms_norm(h, lp["ln1"])
        a, S = _time_mix(lp, n1, tm_x, wkv, cfg)
        h = h + a
        n2 = L.rms_norm(h, lp["ln2"])
        h = h + _channel_mix(lp, n2, cm_x)
        return h, (S.astype(wkv.dtype), n1.astype(tm_x.dtype),
                   n2.astype(cm_x.dtype))

    if cfg.scan_layers:
        x, (wkv, tm_x, cm_x) = jax.lax.scan(
            body, x, (params["layers"], cache["wkv"], cache["tm_x"],
                      cache["cm_x"]))
    else:
        wkvs, tms, cms = [], [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (S, t1, t2) = body(x, (lp, cache["wkv"][i], cache["tm_x"][i],
                                      cache["cm_x"][i]))
            wkvs.append(S); tms.append(t1); cms.append(t2)
        wkv, tm_x, cm_x = jnp.stack(wkvs), jnp.stack(tms), jnp.stack(cms)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", x, params["out_embed"])
    logits = constrain(logits, "dp", "sp", None)
    return logits, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x}


def _prefill_chunked(params, cache, tokens, lens, cfg):
    """Fast chunked prefill: padded positions are state-neutral (w=1, k=0)
    and the token-shift carries are gathered at lengths-1.  Algebraically
    identical to the decode loop but NOT bit-identical: the loop rounds the
    WKV state through the cache dtype every token, the chunked form once."""
    B, T = tokens.shape
    pad = 0 if T <= CHUNK else (-T) % CHUNK
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)))
    pad_mask = jnp.arange(T + pad)[None, :] < lens[:, None]

    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)
    rows = jnp.arange(B)

    def body(h, packed):
        lp, wkv, tm_x, cm_x = packed
        n1 = L.rms_norm(h, lp["ln1"])
        a, S = _time_mix(lp, n1, tm_x, wkv, cfg, pad_mask)
        h = h + a
        n2 = L.rms_norm(h, lp["ln2"])
        h = h + _channel_mix(lp, n2, cm_x)
        new_tm = n1[rows, lens - 1][:, None]
        new_cm = n2[rows, lens - 1][:, None]
        return h, (S.astype(wkv.dtype), new_tm.astype(tm_x.dtype),
                   new_cm.astype(cm_x.dtype))

    if cfg.scan_layers:
        x, (wkv, tm_x, cm_x) = jax.lax.scan(
            body, x, (params["layers"], cache["wkv"], cache["tm_x"],
                      cache["cm_x"]))
    else:
        wkvs, tms, cms = [], [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (S, t1, t2) = body(x, (lp, cache["wkv"][i], cache["tm_x"][i],
                                      cache["cm_x"][i]))
            wkvs.append(S); tms.append(t1); cms.append(t2)
        wkv, tm_x, cm_x = jnp.stack(wkvs), jnp.stack(tms), jnp.stack(cms)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", x[:, :T], params["out_embed"])
    logits = constrain(logits, "dp", "sp", None)
    return logits, {"wkv": wkv, "tm_x": tm_x, "cm_x": cm_x}


def prefill_step(params, cache, tokens, lengths, cache_len, cfg: ArchConfig,
                 use_kernel: bool = False, chunked: bool = False):
    """Batched prefill: whole right-padded prompts in ONE dispatch.

    tokens: (B, T); lengths: (B,) true prompt lengths.  Default mode scans
    single-token decode steps inside the dispatch with a per-row activity
    mask (rows past their length keep their old state verbatim), which makes
    the returned cache and per-row next-token logits BIT-IDENTICAL to the
    token-at-a-time decode loop — including the cache-dtype rounding of the
    WKV state between tokens.  ``chunked=True`` selects the parallel chunked
    formulation (faster, same algebra, float-reassociated).  The caller
    reads row i's next-token logits at position lengths[i]-1."""
    del cache_len, use_kernel   # stateful family: no KV offset, no kernel
    lens = jnp.asarray(lengths, jnp.int32)
    if chunked:
        return _prefill_chunked(params, cache, tokens, lens, cfg)

    def step(c, xt):
        tok_t, t = xt
        logits_t, c_new = decode_step(params, c, tok_t[:, None], None, cfg)
        active = t < lens                                  # (B,)
        def keep(new, old):
            m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)
        return jax.tree.map(keep, c_new, c), logits_t[:, 0]

    T = tokens.shape[1]
    new_cache, logits = jax.lax.scan(
        step, cache, (tokens.T, jnp.arange(T)))
    return logits.swapaxes(0, 1), new_cache
