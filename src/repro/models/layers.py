"""Shared pure-JAX building blocks for the model zoo.

Parameters are plain nested dicts.  A ``Factory`` abstraction lets the same
model-construction code produce either real initialised arrays
(``InitFactory``) or ``PartitionSpec`` trees (``SpecFactory``) so parameter
trees and sharding trees can never drift apart.

Logical sharding axes used throughout (mapped to mesh axes in
``repro.train.sharding``):
    "fsdp"  -> data axis (params sharded on contraction dims, ZeRO-3 style)
    "tp"    -> model axis (tensor parallel: d_ff, vocab)
    "ep"    -> model axis (expert parallel)
    "sp"    -> model axis (sequence parallel activations)
    None    -> replicated
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Param factories
# ---------------------------------------------------------------------------
class InitFactory:
    """Creates initialised parameter arrays."""

    def __init__(self, key: jax.Array, dtype):
        self._key = key
        self.dtype = dtype

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def array(self, shape, axes, *, scale: Optional[float] = None,
              mode: str = "normal"):
        del axes
        if mode == "zeros":
            return jnp.zeros(shape, self.dtype)
        if mode == "ones":
            return jnp.ones(shape, self.dtype)
        if scale is None:
            fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
            scale = 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(self._next(), shape, jnp.float32)
                * scale).astype(self.dtype)


class SpecFactory:
    """Creates PartitionSpec leaves with the same tree structure."""

    def __init__(self):
        self.dtype = None

    def array(self, shape, axes, **kw):
        del kw
        if axes is None:
            return P()
        assert len(axes) == len(shape), (shape, axes)
        return P(*axes)


class ShapeFactory:
    """Creates ShapeDtypeStructs (for abstract init / dry-run)."""

    def __init__(self, dtype):
        self.dtype = dtype

    def array(self, shape, axes, **kw):
        del axes, kw
        return jax.ShapeDtypeStruct(shape, self.dtype)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------
def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    assert d % 2 == 0
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, d // 2, dtype=jnp.float32)
                    / (d // 2))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ w_down


# ---------------------------------------------------------------------------
# Blockwise flash attention (pure jnp, online softmax over KV blocks).
# Memory-bounded: never materialises the full (Tq, Tk) score matrix.
#
# UNROLL_ATTN: the dry-run sets this so the KV-block loop is unrolled into
# straight-line HLO — XLA's HloCostAnalysis counts while-loop bodies ONCE,
# so unrolling is required for honest roofline FLOP/byte accounting.
# ---------------------------------------------------------------------------
UNROLL_ATTN = False


def _blocks(k, block_k):
    B, Tk = k.shape[0], k.shape[1]
    n_blocks = (Tk + block_k - 1) // block_k
    pad = n_blocks * block_k - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
    return k.reshape((B, n_blocks, block_k) + k.shape[2:]), n_blocks, pad


def _block_mask(start, block_k, q_pos, Tk, causal, pad):
    """q_pos: (Tq,) shared positions, or (B, Tq) per-row positions (the
    serving path: one decode dispatch over cache slots at different write
    cursors).  Returns (Tq, bk) or (B, Tq, bk)."""
    k_pos = start + jnp.arange(block_k)
    if causal:
        mask = k_pos <= q_pos[..., :, None]
    else:
        mask = jnp.ones(q_pos.shape + (block_k,), bool)
    if pad:
        mask = mask & (k_pos < Tk)
    return mask


def _expand_mask(mask):
    """Broadcast a (Tq, bk) / (B, Tq, bk) block mask against score blocks
    of shape (B, Hkv, G, Tq, bk)."""
    return mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]


def _q_positions(q_offset, Tq):
    """Absolute query positions: (Tq,) for a shared int/scalar offset,
    (B, Tq) for a per-row offset vector."""
    if getattr(q_offset, "ndim", 0):
        return jnp.asarray(q_offset, jnp.int32)[:, None] + jnp.arange(Tq)
    return q_offset + jnp.arange(Tq)


def _loop(body, carry, xs_blocks, starts, n_blocks):
    """scan or (under UNROLL_ATTN) an unrolled python loop."""
    if UNROLL_ATTN:
        ys = []
        for i in range(n_blocks):
            blk = tuple(x[:, i] for x in xs_blocks) + (i * starts,)
            carry, y = body(carry, blk)
            ys.append(y)
        stacked = (None if ys[0] is None else
                   jax.tree.map(lambda *a: jnp.stack(a, 1), *ys))
        return carry, stacked
    swapped = tuple(x.swapaxes(0, 1) for x in xs_blocks)
    idx = jnp.arange(n_blocks) * starts
    carry, ys = jax.lax.scan(body, carry, swapped + (idx,))
    if ys is not None:
        ys = jax.tree.map(lambda a: a.swapaxes(0, 1), ys)
    return carry, ys


def _flash_fwd_impl(q, k, v, causal, q_offset, block_k, scale):
    B, Tq, Hq, D = q.shape
    Tk, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    block_k = min(block_k, Tk)
    kb, n_blocks, pad = _blocks(k, block_k)
    vb, _, _ = _blocks(v, block_k)
    qg = q.reshape(B, Tq, Hkv, G, D)
    q_pos = _q_positions(q_offset, Tq)

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, start = blk
        s = jnp.einsum("bthgd,bshd->bhgts", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = _expand_mask(_block_mask(start, block_k, q_pos, Tk, causal,
                                        pad))
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        safe_m = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - safe_m[..., None]) * mask
        corr = jnp.exp(m - safe_m)  # m=-inf rows -> corr 0 (safe_m finite)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgts,bshd->bhgtd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Tq, Dv), jnp.float32)
    (m, l, acc), _ = _loop(body, (m0, l0, a0), (kb, vb), block_k, n_blocks)
    l_safe = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)  # (B,Hkv,G,Tq)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hq, Dv)
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, causal, q_offset, block_k, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, block_k, scale)
    return out, (q, k, v, out, lse, q_offset)


def _flash_bwd(causal, block_k, scale, res, dout):
    """Flash backward: recompute p per block from saved lse — O(T) memory."""
    q, k, v, out, lse, q_offset = res
    B, Tq, Hq, D = q.shape
    Tk, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    block_k = min(block_k, Tk)
    kb, n_blocks, pad = _blocks(k, block_k)
    vb, _, _ = _blocks(v, block_k)
    qg = q.reshape(B, Tq, Hkv, G, D).astype(jnp.float32)
    q_pos = _q_positions(q_offset, Tq)
    dog = dout.reshape(B, Tq, Hkv, G, Dv).astype(jnp.float32)
    og = out.reshape(B, Tq, Hkv, G, Dv).astype(jnp.float32)
    # D_i = sum_d do_i * o_i   (B,Hkv,G,Tq)
    Dsum = jnp.einsum("bthgd,bthgd->bhgt", dog, og)

    def body(dq_acc, blk):
        kblk, vblk, start = blk
        kf, vf = kblk.astype(jnp.float32), vblk.astype(jnp.float32)
        s = jnp.einsum("bthgd,bshd->bhgts", qg, kf,
                       preferred_element_type=jnp.float32) * scale
        mask = _expand_mask(_block_mask(start, block_k, q_pos, Tk, causal,
                                        pad))
        # mask BEFORE exp: a masked score above lse would overflow and
        # poison the 0-mask product with NaN
        s = jnp.where(mask, s, -jnp.inf)
        p = jnp.exp(s - lse[..., None])
        dv_blk = jnp.einsum("bhgts,bthgd->bshd", p, dog)
        dp = jnp.einsum("bthgd,bshd->bhgts", dog, vf)
        ds = p * (dp - Dsum[..., None]) * scale
        dq_acc = dq_acc + jnp.einsum("bhgts,bshd->bthgd", ds, kf)
        dk_blk = jnp.einsum("bhgts,bthgd->bshd", ds, qg)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Tq, Hkv, G, D), jnp.float32)
    dq, (dk_blks, dv_blks) = _loop(body, dq0, (kb, vb), block_k, n_blocks)
    dq = dq.reshape(B, Tq, Hq, D).astype(q.dtype)
    dk = dk_blks.reshape(B, n_blocks * block_k, Hkv, D)[:, :Tk].astype(k.dtype)
    dv = dv_blks.reshape(B, n_blocks * block_k, Hkv, Dv)[:, :Tk].astype(v.dtype)
    # q_offset is integer-valued: its cotangent is the symbolic float0 zero
    d_off = np.zeros(np.shape(q_offset), dtype=jax.dtypes.float0)
    return dq, dk, dv, d_off


from functools import partial as _partial


@_partial(jax.custom_vjp, nondiff_argnums=(3, 5, 6))
def _flash(q, k, v, causal, q_offset, block_k, scale):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_offset, block_k, scale)
    return out


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    block_k: int = 1024, softmax_scale: Optional[float] = None):
    """Blockwise flash attention with a flash *backward* (custom VJP):
    only (q, k, v, out, lse) are saved; per-block score matrices are
    recomputed in the backward pass, so memory is O(T) not O(T^2).

    q, k: (B, T, H, D); v: (B, Tk, Hkv, Dv).  GQA via head grouping;
    supports Dv != D (MLA).

    ``q_offset`` is the absolute cache position of query row 0 (causal mask
    admits ``k_pos <= q_offset + row``): a python int (training / static
    prefill), a traced int32 scalar (batched prefill of a continued
    sequence at a dynamic cache position), or a (B,) int32 vector (one
    serving decode dispatch over cache slots at different write cursors).
    Every form rides the flash custom VJP as an int32 *array* argument
    whose cotangent is the symbolic float0 zero — so ``jax.grad`` through
    any offset form takes the real flash backward (training at a cache
    offset works; tracers no longer fall off onto a forward-only impl)."""
    D = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    off = jnp.asarray(q_offset, jnp.int32)
    return _flash(q, k, v, causal, off, block_k, scale)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     softmax_scale: Optional[float] = None):
    """Single-token decode.  q: (B, 1, Hq, D); caches: (B, S, Hkv, D).

    Plain einsum + masked softmax — the seq dim of the cache is sharded over
    the `model` mesh axis; GSPMD turns the max/sum reductions into cross-
    shard collectives (flash-decode pattern).
    """
    B, _, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.asarray(cache_len).reshape(-1, 1)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", (p / l).astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


def cross_entropy(logits, labels, vocab_size: Optional[int] = None):
    """Mean token cross-entropy.  logits: (..., V) possibly padded.

    The label log-prob is computed as sum(logits * one_hot) rather than a
    gather so a vocab-sharded (TP) logits tensor never has to be
    all-gathered — the contraction stays sharded and reduces locally.
    """
    logits = logits.astype(jnp.float32)
    if vocab_size is not None and vocab_size < logits.shape[-1]:
        # mask padded vocab tail (fusable — no materialised copy)
        valid = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(valid, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.bfloat16)
    ll = jnp.sum(logits * oh.astype(jnp.float32), axis=-1)
    return jnp.mean(lse - ll)


def fused_ce(x, out_embed, labels, vocab_size: Optional[int] = None,
             n_chunks: int = 8):
    """Output projection + cross-entropy fused over sequence chunks.

    The (B, T, V) logits tensor is never fully materialised: each chunk
    computes its own logits under jax.checkpoint (recomputed in backward),
    bounding live logits memory to (B, T/n_chunks, V).
    x: (B, T, d); out_embed: (V, d) (possibly vocab-padded).
    """
    from repro.train.sharding import constrain as _cst
    B, T, d = x.shape
    while T % n_chunks:
        n_chunks -= 1
    tc = T // n_chunks
    # un-shard the seq dim here: chunking must not split a sharded dim
    # (536MB for a 4k x 4k hidden — cheap vs. multi-GB logits)
    x = _cst(x, "dp", None, None)

    @jax.checkpoint
    def chunk_loss(xc, lc):
        logits = jnp.einsum("btd,vd->btv", xc, out_embed)
        logits = _cst(logits, "dp", None, "tp")
        return cross_entropy(logits, lc, vocab_size) * lc.size

    xs = x.reshape(B, n_chunks, tc, d).swapaxes(0, 1)
    ls = labels.reshape(B, n_chunks, tc).swapaxes(0, 1)
    if UNROLL_ATTN:  # dry-run: unrolled for honest cost accounting
        total = sum(chunk_loss(xs[i], ls[i]) for i in range(n_chunks))
    else:
        def body(acc, inp):
            xc, lc = inp
            return acc + chunk_loss(xc, lc), None
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / labels.size
