"""Decoder-only LM covering the dense / MLA / MoE / VLM families.

Single parameter layout: per-layer params are stacked along a leading
``n_layers`` axis so the same tree works for ``lax.scan`` (production) and
python-loop (smoke / unrolled dry-run) execution.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import ArchConfig, ParamBucket
from repro.models import layers as L
from repro.train.sharding import constrain


def _cache_write(buf, new, cache_len, T):
    """Write ``new`` (B, T, ...) into cache ``buf`` (B, S, ...) starting at
    ``cache_len``: a shared scalar start uses dynamic_update_slice (the
    single-sequence / uniform-prefill path), a (B,) cursor vector scatters
    each row at its own position (one serving decode dispatch over slots
    whose sequences are at different lengths)."""
    cl = jnp.asarray(cache_len, jnp.int32)
    if cl.ndim:
        rows = jnp.arange(buf.shape[0])[:, None]
        idx = cl[:, None] + jnp.arange(T)[None, :]
        return buf.at[rows, idx].set(new.astype(buf.dtype))
    start = (0, cl) + (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), start)


def _check_capacity(cache_len, T, max_seq):
    """Fail loudly instead of silently clamping: dynamic_update_slice
    clamps out-of-range start indices, which would overwrite the LAST cache
    position forever once a sequence hits max_seq.  Concrete (eager /
    host-side) cache_len values are validated here; compiled dispatches are
    validated by the serving driver before launch (a traced value cannot
    raise)."""
    if isinstance(cache_len, jax.core.Tracer):
        return
    hi = int(np.max(np.asarray(cache_len)))
    if hi + T > max_seq:
        raise ValueError(
            f"KV-cache overflow: cache_len={hi} + {T} new token(s) exceeds "
            f"max_seq={max_seq}; dynamic_update_slice would silently clamp "
            f"and overwrite position {max_seq - 1}. Evict or re-admit the "
            f"sequence with a larger max_seq (init_cache(batch, max_seq)).")


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
def _attn_params(cfg: ArchConfig, f, shape0=()):
    d, dh = cfg.d_model, cfg.d_head
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": f.array(shape0 + (d, Hq * dh), (None,) * len(shape0) + ("fsdp", None)),
        "wk": f.array(shape0 + (d, Hkv * dh), (None,) * len(shape0) + ("fsdp", None)),
        "wv": f.array(shape0 + (d, Hkv * dh), (None,) * len(shape0) + ("fsdp", None)),
        "wo": f.array(shape0 + (Hq * dh, d), (None,) * len(shape0) + ("fsdp", None)),
    }
    if cfg.qk_norm:
        p["q_norm"] = f.array(shape0 + (dh,), None, mode="ones")
        p["k_norm"] = f.array(shape0 + (dh,), None, mode="ones")
    return p


def _mla_params(cfg: ArchConfig, f, shape0=()):
    d, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rot, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ax = (None,) * len(shape0)
    return {
        "w_dq": f.array(shape0 + (d, rq), ax + ("fsdp", None)),
        "q_ln": f.array(shape0 + (rq,), None, mode="ones"),
        "w_uq": f.array(shape0 + (rq, H * (nope + rot)), ax + (None, "tp")),
        "w_dkv": f.array(shape0 + (d, rkv + rot), ax + ("fsdp", None)),
        "kv_ln": f.array(shape0 + (rkv,), None, mode="ones"),
        "w_uk": f.array(shape0 + (rkv, H * nope), ax + (None, "tp")),
        "w_uv": f.array(shape0 + (rkv, H * vd), ax + (None, "tp")),
        "wo": f.array(shape0 + (H * vd, d), ax + ("tp", "fsdp")),
    }


def _mlp_params(cfg: ArchConfig, f, shape0=()):
    d, ff = cfg.d_model, cfg.d_ff
    ax = (None,) * len(shape0)
    return {
        "w_gate": f.array(shape0 + (d, ff), ax + ("fsdp", "tp")),
        "w_up": f.array(shape0 + (d, ff), ax + ("fsdp", "tp")),
        "w_down": f.array(shape0 + (ff, d), ax + ("tp", "fsdp")),
    }


def _moe_params(cfg: ArchConfig, f, shape0=()):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ax = (None,) * len(shape0)
    return {
        "router": f.array(shape0 + (d, E), ax + ("fsdp", None)),
        "w_gate": f.array(shape0 + (E, d, ff), ax + ("ep", "fsdp", None)),
        "w_up": f.array(shape0 + (E, d, ff), ax + ("ep", "fsdp", None)),
        "w_down": f.array(shape0 + (E, ff, d), ax + ("ep", None, "fsdp")),
    }


def _layer_params(cfg: ArchConfig, f, shape0=()):
    p = {"ln1": f.array(shape0 + (cfg.d_model,), None, mode="ones"),
         "ln2": f.array(shape0 + (cfg.d_model,), None, mode="ones")}
    if cfg.family == "mla":
        p["attn"] = _mla_params(cfg, f, shape0)
    else:
        p["attn"] = _attn_params(cfg, f, shape0)
    if cfg.family == "moe":
        p["moe"] = _moe_params(cfg, f, shape0)
    else:
        p["mlp"] = _mlp_params(cfg, f, shape0)
    return p


def n_layer_chunks(cfg: ArchConfig) -> int:
    """Number of layer-stack chunks under ``cfg.layer_chunk`` (DESIGN.md
    §10).  0 and ``n_layers`` both mean the whole-stack layout (ONE chunk,
    param key ``layers`` — byte-identical to the pre-chunking layout);
    any other value must divide ``n_layers``."""
    c = cfg.layer_chunk
    if c in (0, cfg.n_layers):
        return 1
    if c < 0 or cfg.n_layers % c:
        raise ValueError(
            f"layer_chunk={c} must be 0 or a positive divisor of "
            f"n_layers={cfg.n_layers}")
    return cfg.n_layers // c


def chunk_keys(cfg: ArchConfig) -> tuple:
    """Top-level param keys holding the layer stack, in production order:
    ``("layers",)`` for the whole-stack layout, else ``layers0..layersM-1``
    each stacking ``layer_chunk`` consecutive layers."""
    m = n_layer_chunks(cfg)
    if m == 1:
        return ("layers",)
    return tuple(f"layers{i}" for i in range(m))


def layer_stack(params: dict, cfg: ArchConfig):
    """The full ``(n_layers, ...)`` stacked layer tree, concatenating chunk
    stacks when the params are in a chunked layout (decode / rechunk)."""
    if "layers" in params:
        return params["layers"]
    keys = chunk_keys(cfg)
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                        *[params[k] for k in keys])


def rechunk_params(params: dict, cfg: ArchConfig, layer_chunk: int) -> dict:
    """Convert a params tree between ``layer_chunk`` layouts (checkpoint
    portability: ``CheckpointManager.restore`` validates leaf shapes against
    its template, so a checkpoint written at one chunking must be rechunked
    — concat + re-split along the layer axis — before training at another).
    Non-layer keys pass through untouched."""
    import dataclasses as _dc
    stack = layer_stack(params, cfg)
    out = {k: v for k, v in params.items()
           if k != "layers" and not (k.startswith("layers") and
                                     k[len("layers"):].isdigit())}
    new_cfg = _dc.replace(cfg, layer_chunk=layer_chunk)
    keys = chunk_keys(new_cfg)
    if len(keys) == 1:
        out["layers"] = stack
        return out
    c = cfg.n_layers // len(keys)
    for m, k in enumerate(keys):
        out[k] = jax.tree.map(lambda a, m=m: a[m * c:(m + 1) * c], stack)
    return out


def bucket_spec(cfg: ArchConfig) -> tuple:
    """ParamBuckets (DESIGN.md §6, §10) in production (forward) order: the
    token embedding produces activations first, then each layer-stack chunk,
    then the norm/output head.  With ``layer_chunk == 0`` the whole
    ``layers`` stack is ONE bucket (per-layer params live stacked along a
    leading ``n_layers`` axis inside a single leaf — the ``lax.scan``
    layout); ``layer_chunk == c`` splits the stack into ``n_layers/c``
    per-chunk buckets, the granularity at which the worker mesh exchanges,
    compresses, and non-instantly updates LM gradients."""
    order = ["embed"]
    if cfg.family == "vlm":
        order.append("patch_proj")
    order += list(chunk_keys(cfg)) + ["final_norm"]
    if not cfg.tie_embeddings:
        order.append("out_embed")
    return tuple(ParamBucket(name=k, keys=(k,), index=i)
                 for i, k in enumerate(order))


def build_params(cfg: ArchConfig, f):
    Vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": f.array((Vp, d), ("tp", "fsdp"), scale=0.02),
        "final_norm": f.array((d,), None, mode="ones"),
    }
    keys = chunk_keys(cfg)
    if len(keys) == 1:
        params["layers"] = _layer_params(cfg, f, (cfg.n_layers,))
    else:
        for k in keys:
            params[k] = _layer_params(cfg, f, (cfg.layer_chunk,))
    if not cfg.tie_embeddings:
        params["out_embed"] = f.array((Vp, d), ("tp", "fsdp"), scale=0.02)
    if cfg.family == "vlm":
        params["patch_proj"] = f.array((d, d), ("fsdp", None))
    return params


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------
def _gqa_attention(p, x, cfg: ArchConfig, positions, kv_cache=None,
                   cache_len=None, use_kernel: bool = False):
    """Returns (out, new_kv) ; kv_cache: (k, v) each (B, S, Hkv, dh).

    Cached attention runs causally at absolute offset ``cache_len``
    (scalar: uniform prefill/decode; (B,) vector: per-slot serving decode)
    through the same flash path for any T — so a T-token batched prefill
    is bit-identical, row for row, to T single-token decode steps."""
    B, T, d = x.shape
    dh, Hq, Hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, T, Hq, dh)
    k = (x @ p["wk"]).reshape(B, T, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, T, Hkv, dh)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if kv_cache is None:
        q = constrain(q, "dp", "sp", None, None)
        if use_kernel:
            # training-grade Pallas flash attention: kernel forward with a
            # real backward (recompute-bwd custom VJP, autotuned blocks)
            from repro.kernels.flash_attention import flash_attention_train
            o = flash_attention_train(q, k, v, causal=True)
        else:
            o = L.flash_attention(q, k, v, causal=True)
        new_kv = None
    else:
        ck, cv = kv_cache
        cl = jnp.asarray(cache_len, jnp.int32)
        ck = _cache_write(ck, k, cache_len, T)
        cv = _cache_write(cv, v, cache_len, T)
        ck = constrain(ck, "dp", "sp", None, None)
        cv = constrain(cv, "dp", "sp", None, None)
        off = cl if cl.ndim else cache_len
        if use_kernel and not cl.ndim:
            from repro.kernels.flash_attention import flash_attention_fwd
            from repro.kernels.ops import _interpret
            o = flash_attention_fwd(
                q.transpose(0, 2, 1, 3), ck.transpose(0, 2, 1, 3),
                cv.transpose(0, 2, 1, 3), causal=True, q_offset=off,
                interpret=_interpret()).transpose(0, 2, 1, 3)
        else:
            o = L.flash_attention(q, ck, cv, causal=True, q_offset=off)
        new_kv = (ck, cv)
    o = o.reshape(B, T, Hq * dh)
    return o @ p["wo"], new_kv


def _mla_attention(p, x, cfg: ArchConfig, positions, kv_cache=None,
                   cache_len=None):
    """MLA.  Cache holds the *compressed* kv latent + shared rope key.

    Decode uses the absorbed formulation (q projected into latent space) so
    per-step work is O(S * (r_kv + r_rope)) per head — the standard MLA
    serving optimisation.
    """
    B, T, d = x.shape
    H = cfg.n_heads
    rkv, nope, rot, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                          cfg.qk_rope_dim, cfg.v_head_dim)
    cq = L.rms_norm(x @ p["w_dq"], p["q_ln"])
    qfull = (cq @ p["w_uq"]).reshape(B, T, H, nope + rot)
    q_nope, q_rope = qfull[..., :nope], qfull[..., nope:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]
    c_kv = L.rms_norm(dkv[..., :rkv], p["kv_ln"])  # (B,T,rkv)
    k_rope = L.rope(dkv[..., rkv:][:, :, None, :], positions,
                    cfg.rope_theta)[:, :, 0, :]  # shared across heads

    scale = 1.0 / math.sqrt(nope + rot)
    w_uk = p["w_uk"].reshape(rkv, H, nope)
    w_uv = p["w_uv"].reshape(rkv, H, vd)

    if kv_cache is None:
        k_nope = jnp.einsum("btr,rhn->bthn", c_kv, w_uk)
        v = jnp.einsum("btr,rhv->bthv", c_kv, w_uv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, rot))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, "dp", "sp", None, None)
        o = L.flash_attention(q, k, v, causal=True, softmax_scale=scale)
        new_kv = None
    else:
        cc, cr = kv_cache  # (B,S,rkv), (B,S,rot)
        cl = jnp.asarray(cache_len, jnp.int32)
        cc = _cache_write(cc, c_kv, cache_len, T)
        cr = _cache_write(cr, k_rope, cache_len, T)
        cc = constrain(cc, "dp", "sp", None)
        cr = constrain(cr, "dp", "sp", None)
        # absorbed: q_c = q_nope absorbed through w_uk  -> latent space
        q_c = jnp.einsum("bthn,rhn->bthr", q_nope, w_uk)
        s = (jnp.einsum("bthr,bsr->bhts", q_c.astype(jnp.float32),
                        cc.astype(jnp.float32))
             + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                          cr.astype(jnp.float32))) * scale
        S = cc.shape[1]
        # CAUSAL mask at the absolute offset: query row t sits at cache
        # position cache_len + t and may see k_pos <= that.  The previous
        # ``k_pos < cache_len + T`` window is only causal for T == 1 — a
        # T-token batched prefill through it would attend to future tokens.
        q_pos = L._q_positions(cl if cl.ndim else cache_len, T)
        mask = jnp.arange(S) <= q_pos[..., :, None]      # (T,S) or (B,T,S)
        mask = mask[None, None] if mask.ndim == 2 else mask[:, None]
        s = jnp.where(mask, s, -jnp.inf)
        a = jax.nn.softmax(s, axis=-1)
        o_c = jnp.einsum("bhts,bsr->bthr", a.astype(cc.dtype), cc)
        o = jnp.einsum("bthr,rhv->bthv", o_c, w_uv)
        new_kv = (cc, cr)
    o = o.reshape(B, T, H * vd)
    return o @ p["wo"], new_kv


GROUP_TOKENS = 256


def moe_block(p, x, cfg: ArchConfig):
    """Grouped GShard-style top-k dispatch with capacity.  x: (B, T, d).

    Token groups are formed by *splitting the sequence dim in place*
    ((B, T, d) -> (B, T/g, g, d)) — a tile-compatible reshape under
    (dp, sp) activation sharding, so no involuntary resharding.  The
    position-in-expert cumsum stays group-local.  Experts are sharded over
    `ep`; the xg->xe dispatch einsum is the (GSPMD-inserted) all-to-all.
    Returns (y, aux_loss).
    """
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    if T >= GROUP_TOKENS:
        g = GROUP_TOKENS
        nsub = T // g
        xg = x.reshape(B, nsub, g, d)
        xg = constrain(xg, "dp", "sp", None, None)
    else:  # decode-sized: one group over the whole (tiny) token set
        g = B * T
        nsub = 1
        xg = x.reshape(1, 1, g, d)

    logits = jnp.einsum("bntd,de->bnte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, K)  # (b,n,t,K)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    C = max(int(math.ceil(g * K / E * cfg.capacity_factor)), min(g, K))
    # one-hot over experts per k: (b,n,t,K,E)
    oh = jax.nn.one_hot(gate_i, E, dtype=jnp.float32)
    # position of each (t,k) within its expert queue (cumsum is group-local)
    pos = jnp.cumsum(oh.reshape(*oh.shape[:2], g * K, E), axis=2) - 1.0
    pos = pos.reshape(oh.shape)
    pos_k = jnp.sum(pos * oh, axis=-1)  # (b,n,t,K)
    keep = pos_k < C
    gate_w = gate_w * keep

    # dispatch/combine tensors: (b,n,t,E,C); bf16 halves a2a volume
    pos_oh = jax.nn.one_hot(pos_k.astype(jnp.int32), C,
                            dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("bntke,bntkc->bntec", oh, pos_oh).astype(x.dtype)
    combine = jnp.einsum("bntk,bntke,bntkc->bntec", gate_w, oh,
                         pos_oh).astype(x.dtype)

    xe = jnp.einsum("bntec,bntd->bnecd", dispatch, xg)
    xe = constrain(xe, "dp", None, "ep", None, None)
    h = jnp.einsum("bnecd,edf->bnecf", xe, p["w_gate"])
    u = jnp.einsum("bnecd,edf->bnecf", xe, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("bnecf,efd->bnecd", h, p["w_down"])
    ye = constrain(ye, "dp", None, "ep", None, None)
    y = jnp.einsum("bntec,bnecd->bntd", combine, ye)

    # load-balance aux loss (Switch):  E * sum_e f_e * P_e
    me = probs.mean(axis=(1, 2))                    # (b,E)
    ce = oh.sum(axis=3).mean(axis=(1, 2))           # fraction routed
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    return y.reshape(B, T, d), aux


def _block(p, x, cfg: ArchConfig, positions, kv_cache=None, cache_len=None,
           use_kernel: bool = False):
    attn_fn = _mla_attention if cfg.family == "mla" else _gqa_attention
    kw = {"use_kernel": use_kernel} if attn_fn is _gqa_attention else {}
    a, new_kv = attn_fn(p["attn"], L.rms_norm(x, p["ln1"]), cfg, positions,
                        kv_cache, cache_len, **kw)
    x = x + a
    h = L.rms_norm(x, p["ln2"])
    if cfg.family == "moe":
        m, aux = moe_block(p["moe"], h, cfg)
    else:
        m = L.swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                     p["mlp"]["w_down"])
        aux = jnp.zeros((), jnp.float32)
    x = x + m
    x = constrain(x, "dp", "sp", None)
    return x, aux, new_kv


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------
def _chunk_forward(stack, x, aux, cfg: ArchConfig, positions,
                   use_kernel: bool = False):
    """Run one stacked chunk of layers: ``lax.scan`` when the config scans
    and the chunk holds more than one layer, else an unrolled python loop
    (so ``layer_chunk=1`` is bit-identical to the unrolled layout)."""
    c = jax.tree.leaves(stack)[0].shape[0]
    f = lambda lp_, h_: _block(lp_, h_, cfg, positions,
                               use_kernel=use_kernel)[:2]
    if cfg.remat:
        f = jax.checkpoint(f)
    if cfg.scan_layers and c > 1:
        def body(carry, lp):
            h, a = carry
            h, ai = f(lp, h)
            return (h, a + ai), None
        (x, aux), _ = jax.lax.scan(body, (x, aux), stack)
    else:
        for i in range(c):
            lp = jax.tree.map(lambda a, i=i: a[i], stack)
            x, ai = f(lp, x)
            aux = aux + ai
    return x, aux


def _stack_forward(params, x, cfg: ArchConfig, positions,
                   use_kernel: bool = False):
    """Run all layers (training / prefill path, no cache), chunk by chunk
    in production order (one chunk total under the whole-stack layout)."""
    aux = jnp.zeros((), jnp.float32)
    for key in chunk_keys(cfg):
        x, aux = _chunk_forward(params[key], x, aux, cfg, positions,
                                use_kernel)
    return x, aux


def embed_tokens(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "dp", "sp", None)


def logits_fn(params, x, cfg: ArchConfig):
    out = params.get("out_embed", params["embed"])
    logits = jnp.einsum("btd,vd->btv", x, out)
    return constrain(logits, "dp", "sp", None)


def forward(params, tokens, cfg: ArchConfig, patch_embeds=None,
            return_hidden: bool = False, use_kernel: bool | None = None):
    """Training / prefill forward.  tokens: (B, T) int32.  ``use_kernel``
    (default: ``cfg.use_kernel``) routes GQA attention through the
    trainable Pallas flash kernel."""
    uk = cfg.use_kernel if use_kernel is None else use_kernel
    x = embed_tokens(params, tokens, cfg)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = patch_embeds @ params["patch_proj"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]
    x, aux = _stack_forward(params, x, cfg, positions, use_kernel=uk)
    x = L.rms_norm(x, params["final_norm"])
    if cfg.family == "vlm" and patch_embeds is not None:
        x = x[:, patch_embeds.shape[1]:]
    if return_hidden:
        return x, aux
    return logits_fn(params, x, cfg), aux


def loss_fn(params, batch, cfg: ArchConfig):
    x, aux = forward(params, batch["tokens"], cfg,
                     patch_embeds=batch.get("patch_embeds"),
                     return_hidden=True)
    out = params.get("out_embed", params["embed"])
    ce = L.fused_ce(x, out, batch["labels"], cfg.vocab_size)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def loss_and_shard_bucket_grads(params, shards, cfg: ArchConfig, on_bucket):
    """Worker-mesh interleaved tape for the LM family (DESIGN.md §8, §10):
    the chunked backward walk over a stack of micro-shards, firing
    ``on_bucket`` the moment each bucket's STACKED gradient is produced.

    ``shards`` is the token batch pytree with a leading ``(s, b, T)``
    micro-shard axis.  Output matches ``lax.map(value_and_grad(loss_fn))``
    over that axis to ~1 ulp — the forward runs chunk by chunk saving each
    chunk's stacked input activations, then the backward re-linearises one
    chunk at a time (with ``cfg.remat`` the whole-graph backward recomputes
    blocks anyway, so the tape's extra forward is the remat recompute it
    replaces) so ``on_bucket(bucket, {key: dp_stacked})`` can issue that
    chunk's exchange collective while earlier chunks' backward is still to
    run.  Bucket firing order is reverse-production: out_embed (untied) ->
    final_norm -> chunks descending -> embed, with the tied-CE embedding
    contribution folded into the embed bucket.  ``on_bucket`` tokens are
    tied into the downstream cotangent (``core/chaos.py::delay_tie``) so
    XLA cannot sink a collective's issue point to the end of the step."""
    from repro.core.chaos import delay_tie
    if "patch_embeds" in shards:
        raise NotImplementedError(
            "the LM shard tape does not take VLM patch embeddings; run the "
            "worker mesh without --interleave for patch-embed batches")
    buckets = {b.name: b for b in bucket_spec(cfg)}
    tokens, labels = shards["tokens"], shards["labels"]
    T = tokens.shape[-1]
    positions = jnp.arange(T)[None, :]
    uk = cfg.use_kernel
    ckeys = chunk_keys(cfg)
    f32 = lambda t: jax.tree.map(lambda a: a.astype(jnp.float32), t)

    # forward, saving each chunk's stacked (s, b, T, d) input activations
    xs = jax.lax.map(lambda t: embed_tokens(params, t, cfg), tokens)
    chunk_in, auxes = [], []
    for key in ckeys:
        chunk_in.append(xs)

        def run_chunk(x, st=params[key]):
            return _chunk_forward(st, x, jnp.zeros((), jnp.float32), cfg,
                                  positions, uk)

        xs, aux_m = jax.lax.map(run_chunk, xs)
        auxes.append(aux_m)
    aux = sum(auxes)  # (s,)

    # head: rms_norm + fused CE — per-shard loss, head grads, and dy in
    # ONE vjp (the head params are cheap; no re-linearisation here)
    out_key = "out_embed" if "out_embed" in params else "embed"
    head_p = {"final_norm": params["final_norm"], "out": params[out_key]}

    def head_loss_dy(args):
        x, lab = args

        def head_fn(hp, x_):
            h = L.rms_norm(x_, hp["final_norm"])
            return L.fused_ce(h, hp["out"], lab, cfg.vocab_size)

        ce, vjp = jax.vjp(head_fn, head_p, x)
        dhp, dx = vjp(jnp.ones((), ce.dtype))
        return ce, f32(dhp), dx

    ces, dhead, dy = jax.lax.map(head_loss_dy, (xs, labels))
    losses = ces + 0.01 * aux
    metrics = {"ce": ces, "aux": aux}

    grads = {}
    if out_key == "out_embed":
        grads["out_embed"] = dhead["out"]
        dy = delay_tie(dy, on_bucket(buckets["out_embed"],
                                     {"out_embed": grads["out_embed"]}))
    grads["final_norm"] = dhead["final_norm"]
    dy = delay_tie(dy, on_bucket(buckets["final_norm"],
                                 {"final_norm": grads["final_norm"]}))

    for key, x_in in zip(reversed(ckeys), reversed(chunk_in)):
        def bwd_chunk(args, st=params[key]):
            x, g = args

            def run(st_, x_):
                return _chunk_forward(st_, x_, jnp.zeros((), jnp.float32),
                                      cfg, positions, uk)

            _, vjp = jax.vjp(run, st, x)
            # cotangents: dy chains through the chunk's hidden-state output;
            # the aux output enters the loss directly at weight 0.01
            dst, dx = vjp((g, jnp.asarray(0.01, jnp.float32)))
            return f32(dst), dx

        dp, dy = jax.lax.map(bwd_chunk, (x_in, dy))
        grads[key] = dp
        dy = delay_tie(dy, on_bucket(buckets[key], {key: dp}))

    if cfg.family == "vlm":
        # patch_proj is unused without patch embeddings: zero grads, same
        # as value_and_grad over the whole graph
        s = tokens.shape[0]
        pp = jnp.zeros((s,) + params["patch_proj"].shape, jnp.float32)
        grads["patch_proj"] = pp
        dy = delay_tie(dy, on_bucket(buckets["patch_proj"],
                                     {"patch_proj": pp}))

    def bwd_embed(args):
        t, g = args

        def emb(ep):
            return embed_tokens({"embed": ep}, t, cfg)

        _, vjp = jax.vjp(emb, params["embed"])
        (de,) = vjp(g)
        return de.astype(jnp.float32)

    d_embed = jax.lax.map(bwd_embed, (tokens, dy))
    if out_key == "embed":
        d_embed = d_embed + dhead["out"]  # tied CE head contribution
    grads["embed"] = d_embed
    losses = delay_tie(losses, on_bucket(buckets["embed"],
                                         {"embed": d_embed}))
    return losses, metrics, grads


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, f):
    if cfg.family == "mla":
        per_layer = {
            "c_kv": f.array((cfg.n_layers, batch, max_seq, cfg.kv_lora_rank),
                            (None, "dp", "sp", None), mode="zeros"),
            "k_rope": f.array((cfg.n_layers, batch, max_seq, cfg.qk_rope_dim),
                              (None, "dp", "sp", None), mode="zeros"),
        }
    else:
        shp = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
        per_layer = {
            "k": f.array(shp, (None, "dp", "sp", None, None), mode="zeros"),
            "v": f.array(shp, (None, "dp", "sp", None, None), mode="zeros"),
        }
    return per_layer


def _cache_pair(cache, cfg):
    return ("c_kv", "k_rope") if cfg.family == "mla" else ("k", "v")


def decode_step(params, cache, tokens, cache_len, cfg: ArchConfig,
                use_kernel: bool = False):
    """Cached forward at absolute cache offset ``cache_len``.

    tokens: (B, T) — T == 1 is one decode step, T > 1 is a batched prefill
    (whole prompt in ONE dispatch; the causal mask runs at the absolute
    offset, so a continued sequence never attends to future tokens).
    ``cache_len``: scalar (shared offset) or (B,) per-slot write cursors.
    ``use_kernel`` routes GQA prefill attention through the Pallas
    flash kernel (scalar offsets only).  Returns (logits, new_cache)."""
    B, T = tokens.shape
    k1, k2 = _cache_pair(cache, cfg)
    _check_capacity(cache_len, T, cache[k1].shape[2])
    x = embed_tokens(params, tokens, cfg)
    cl = jnp.asarray(cache_len, jnp.int32)
    positions = (cl[:, None] + jnp.arange(T)[None, :] if cl.ndim
                 else (cl + jnp.arange(T))[None, :])

    stack = layer_stack(params, cfg)
    if cfg.scan_layers:
        def body(h, packed):
            lp, c1, c2 = packed
            h, a, new_kv = _block(lp, h, cfg, positions, (c1, c2), cache_len,
                                  use_kernel)
            return h, new_kv
        x, (nk1, nk2) = jax.lax.scan(body, x,
                                     (stack, cache[k1], cache[k2]))
    else:
        nk1s, nk2s = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], stack)
            x, a, new_kv = _block(lp, x, cfg, positions,
                                  (cache[k1][i], cache[k2][i]), cache_len,
                                  use_kernel)
            nk1s.append(new_kv[0]); nk2s.append(new_kv[1])
        nk1, nk2 = jnp.stack(nk1s), jnp.stack(nk2s)
    x = L.rms_norm(x, params["final_norm"])
    logits = logits_fn(params, x, cfg)
    return logits, {k1: nk1, k2: nk2}


def prefill_step(params, cache, tokens, lengths, cache_len, cfg: ArchConfig,
                 use_kernel: bool = False):
    """Batched prefill: whole (right-padded) prompts in one dispatch.

    ``lengths`` (B,) true prompt lengths are bookkeeping for the caller —
    KV written past a row's true length is junk but unreachable: the
    serving cursor only advances to the true length, and every later
    attention masks ``k_pos <= q_pos < cursor``.  The caller gathers row
    i's next-token logits at position ``lengths[i] - 1``."""
    del lengths
    return decode_step(params, cache, tokens, cache_len, cfg,
                       use_kernel=use_kernel)
