"""Encoder-decoder transformer backbone (whisper-small).

The conv/audio frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, enc_frames, d_model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import layers as L
from repro.models.lm import _attn_params, _mlp_params
from repro.train.sharding import constrain


def _xattn_params(cfg: ArchConfig, f, shape0=()):
    d, dh = cfg.d_model, cfg.d_head
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    ax = (None,) * len(shape0)
    return {
        "wq": f.array(shape0 + (d, Hq * dh), ax + ("fsdp", None)),
        "wk": f.array(shape0 + (d, Hkv * dh), ax + ("fsdp", None)),
        "wv": f.array(shape0 + (d, Hkv * dh), ax + ("fsdp", None)),
        "wo": f.array(shape0 + (Hq * dh, d), ax + ("fsdp", None)),
    }


def build_params(cfg: ArchConfig, f):
    Vp, d = cfg.padded_vocab, cfg.d_model
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    return {
        "embed": f.array((Vp, d), ("tp", "fsdp"), scale=0.02),
        "pos_dec": f.array((8192, d), (None, "fsdp"), scale=0.01),
        "final_norm": f.array((d,), None, mode="ones"),
        "enc_final_norm": f.array((d,), None, mode="ones"),
        "enc_layers": {
            "ln1": f.array((Le, d), None, mode="ones"),
            "ln2": f.array((Le, d), None, mode="ones"),
            "attn": _attn_params(cfg, f, (Le,)),
            "mlp": _mlp_params(cfg, f, (Le,)),
        },
        "dec_layers": {
            "ln1": f.array((Ld, d), None, mode="ones"),
            "ln2": f.array((Ld, d), None, mode="ones"),
            "ln3": f.array((Ld, d), None, mode="ones"),
            "attn": _attn_params(cfg, f, (Ld,)),
            "xattn": _xattn_params(cfg, f, (Ld,)),
            "mlp": _mlp_params(cfg, f, (Ld,)),
        },
    }


def _mha(p, xq, xkv, cfg, *, causal, positions=None, kv_cache=None,
         cache_len=None):
    B, Tq, d = xq.shape
    dh, Hq, Hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    q = (xq @ p["wq"]).reshape(B, Tq, Hq, dh)
    if kv_cache is not None and xkv is None:
        # cross-attention decode: cached K/V, no new keys
        k, v = kv_cache
        o = L.decode_attention(q, k, v, k.shape[1])
        return (o.reshape(B, Tq, Hq * dh)) @ p["wo"], kv_cache
    Tk = xkv.shape[1]
    k = (xkv @ p["wk"]).reshape(B, Tk, Hkv, dh)
    v = (xkv @ p["wv"]).reshape(B, Tk, Hkv, dh)
    if positions is not None:
        q = L.rope(q, positions, cfg.rope_theta)
        kpos = jnp.arange(Tk)[None, :] if cache_len is None else (
            jnp.asarray(cache_len) + jnp.arange(Tk)[None, :])
        k = L.rope(k, kpos, cfg.rope_theta)
    if kv_cache is not None:  # self-attention decode
        ck, cv = kv_cache
        idx = jnp.asarray(cache_len)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, idx, 0, 0))
        o = L.decode_attention(q, ck, cv, cache_len + Tq)
        return (o.reshape(B, Tq, Hq * dh)) @ p["wo"], (ck, cv)
    q = constrain(q, "dp", "sp", None, None)
    o = L.flash_attention(q, k, v, causal=causal)
    return (o.reshape(B, Tq, Hq * dh)) @ p["wo"], None


def encode(params, frames, cfg: ArchConfig):
    """frames: (B, F, d_model) stub embeddings."""
    x = constrain(frames, "dp", None, None)
    Le = cfg.n_enc_layers

    def body(h, lp):
        def f(lp_, h_):
            a, _ = _mha(lp_["attn"], L.rms_norm(h_, lp_["ln1"]),
                        L.rms_norm(h_, lp_["ln1"]), cfg, causal=False)
            h_ = h_ + a
            h_ = h_ + L.swiglu(L.rms_norm(h_, lp_["ln2"]), lp_["mlp"]["w_gate"],
                               lp_["mlp"]["w_up"], lp_["mlp"]["w_down"])
            return constrain(h_, "dp", None, None)
        if cfg.remat:
            f = jax.checkpoint(f)
        return f(lp, h), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(Le):
            lp = jax.tree.map(lambda a, i=i: a[i], params["enc_layers"])
            x, _ = body(x, lp)
    return L.rms_norm(x, params["enc_final_norm"])


def _dec_block(lp, x, enc_out, cfg, positions, self_cache=None,
               cross_cache=None, cache_len=None):
    a, new_self = _mha(lp["attn"], L.rms_norm(x, lp["ln1"]),
                       L.rms_norm(x, lp["ln1"]), cfg, causal=True,
                       positions=positions, kv_cache=self_cache,
                       cache_len=cache_len)
    x = x + a
    if cross_cache is not None:
        a, _ = _mha(lp["xattn"], L.rms_norm(x, lp["ln2"]), None, cfg,
                    causal=False, kv_cache=cross_cache)
    else:
        a, _ = _mha(lp["xattn"], L.rms_norm(x, lp["ln2"]), enc_out, cfg,
                    causal=False)
    x = x + a
    x = x + L.swiglu(L.rms_norm(x, lp["ln3"]), lp["mlp"]["w_gate"],
                     lp["mlp"]["w_up"], lp["mlp"]["w_down"])
    return constrain(x, "dp", "sp", None), new_self


def forward(params, tokens, frames, cfg: ArchConfig,
            return_hidden: bool = False):
    enc_out = encode(params, frames, cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def body(h, lp):
        def f(lp_, h_):
            return _dec_block(lp_, h_, enc_out, cfg, positions)[0]
        if cfg.remat:
            f = jax.checkpoint(f)
        return f(lp, h), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
    else:
        f = lambda lp_, h_: _dec_block(lp_, h_, enc_out, cfg, positions)[0]
        if cfg.remat:
            f = jax.checkpoint(f)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], params["dec_layers"])
            x = f(lp, x)
    x = L.rms_norm(x, params["final_norm"])
    if return_hidden:
        return x, jnp.zeros((), jnp.float32)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"])
    return constrain(logits, "dp", "sp", None), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: ArchConfig):
    x, aux = forward(params, batch["tokens"], batch["frames"], cfg,
                     return_hidden=True)
    ce = L.fused_ce(x, params["embed"], batch["labels"], cfg.vocab_size)
    return ce, {"ce": ce, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, f):
    Ld, dh, Hkv = cfg.n_layers, cfg.d_head, cfg.n_kv_heads
    F = cfg.enc_frames
    return {
        "k": f.array((Ld, batch, max_seq, Hkv, dh),
                     (None, "dp", "sp", None, None), mode="zeros"),
        "v": f.array((Ld, batch, max_seq, Hkv, dh),
                     (None, "dp", "sp", None, None), mode="zeros"),
        "xk": f.array((Ld, batch, F, Hkv, dh),
                      (None, "dp", None, None, None), mode="zeros"),
        "xv": f.array((Ld, batch, F, Hkv, dh),
                      (None, "dp", None, None, None), mode="zeros"),
    }


def decode_step(params, cache, tokens, cache_len, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "sp", None)
    positions = jnp.full((1, 1), cache_len, jnp.int32)

    def body(h, packed):
        lp, k, v, xk, xv = packed
        h, new_self = _dec_block(lp, h, None, cfg, positions,
                                 self_cache=(k, v), cross_cache=(xk, xv),
                                 cache_len=cache_len)
        return h, new_self

    if cfg.scan_layers:
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
    else:
        nks, nvs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["dec_layers"])
            x, (k_, v_) = body(x, (lp, cache["k"][i], cache["v"][i],
                                   cache["xk"][i], cache["xv"][i]))
            nks.append(k_); nvs.append(v_)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    x = L.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("btd,vd->btv", x, params["embed"])
    logits = constrain(logits, "dp", "sp", None)
    return logits, {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
