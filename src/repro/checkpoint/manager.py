"""Fault-tolerant checkpointing (no orbax in this container).

- Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
- keep_n: old checkpoints garbage-collected.
- Resharding restore: arrays are saved device-agnostic (numpy); on restore
  they are placed under the *current* mesh's shardings — so a job can come
  back on a different topology (elastic scaling / failed-pod recovery).
- Async save: optional background thread so the training loop is not
  blocked by I/O (the step's arrays are snapshotted to host first).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = True):
        # never run two writers at once: a pending async save for the same
        # step would share (and race on) this save's tmp.<step> directory
        self.wait()
        leaves, treedef = _flatten(state)
        # device -> host now; non-native dtypes (bfloat16) are stored as
        # float32 (lossless upcast) and cast back on restore
        host_leaves = []
        for l in leaves:
            a = np.asarray(l)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = np.asarray(jax.numpy.asarray(l).astype("float32"))
            host_leaves.append(a)
        if blocking:
            self._write(step, host_leaves, treedef)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": l for i, l in enumerate(host_leaves)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "treedef": str(treedef)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of `like`.  If `shardings` is given
        (same tree structure), arrays are device_put with those shardings —
        this is what makes restore topology-independent."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}", "arrays.npz")
        data = np.load(path)
        leaves_like, treedef = _flatten(like)
        n = len(leaves_like)
        arrs = [data[f"a{i}"] for i in range(n)]
        # global shapes must match the template exactly — resharding restore
        # changes device placement, never array shape.  Without this check a
        # worker-stacked (N, ...) checkpoint (localsgd / chaos τ>=1)
        # restored under a different worker count would silently drop
        # workers' diverged state downstream instead of erroring here.  The
        # error names the offending leaf's tree path and both shapes so a
        # mismatch in a 100-leaf TrainState is diagnosable at a glance.
        keyed_leaves, _ = jax.tree_util.tree_flatten_with_path(like)
        for i, (a, l) in enumerate(zip(arrs, leaves_like)):
            if hasattr(l, "shape") and tuple(a.shape) != tuple(l.shape):
                leaf_path = jax.tree_util.keystr(keyed_leaves[i][0])
                raise ValueError(
                    f"checkpoint leaf {i} at {leaf_path}: checkpoint has "
                    f"shape {tuple(a.shape)} but the restore template "
                    f"expects {tuple(l.shape)}: the checkpoint was written "
                    f"under a different state layout (e.g. a worker-stacked "
                    f"localsgd / chaos staleness>=1 checkpoint resumed with "
                    f"a different --workers — stacked checkpoints pin the "
                    f"worker count; bsp and chaos staleness=0 checkpoints "
                    f"are worker-count-invariant)")
        # cast back through jnp: numpy lacks cast kernels for bf16 & friends
        arrs = [np.asarray(jax.numpy.asarray(a).astype(l.dtype))
                if hasattr(l, "dtype") and a.dtype != l.dtype else a
                for a, l in zip(arrs, leaves_like)]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            arrs = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                    for a, s in zip(arrs, sh_leaves)]
        else:
            arrs = [jax.device_put(a) for a in arrs]
        return treedef.unflatten(arrs), step
