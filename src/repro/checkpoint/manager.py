"""Fault-tolerant checkpointing (no orbax in this container).

- Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-save never corrupts the latest checkpoint.
- keep_n: old checkpoints garbage-collected.
- Resharding restore: arrays are saved device-agnostic (numpy); on restore
  they are placed under the *current* mesh's shardings — so a job can come
  back on a different topology (elastic scaling / failed-pod recovery).
- Async save: optional background thread so the training loop is not
  blocked by I/O (the step's arrays are snapshotted to host first).
- Payload validation: the manifest stamps the payload's byte length and
  CRC32; ``restore`` verifies both before unpickling, so a torn/truncated
  write (power loss after the rename, a lying filesystem) is DETECTED and
  the manager falls back to the newest older checkpoint that validates
  instead of crashing mid-restore.  Pre-checksum checkpoints (no ``crc32``
  in the manifest) still restore — validation is skipped for them.
- Transient-IO retry: payload reads are retried ``io_retries`` times with
  bounded exponential backoff before a fallback/raise, so a blip on a
  network filesystem does not abort a resume.
- ``fault`` is an optional injector (``launch/faults.py``) whose hooks fire
  after a checkpoint lands (torn-write simulation) and before each payload
  read (transient-IO simulation) — the deterministic test surface for all
  of the above.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointCorrupt(Exception):
    """A checkpoint directory failed validation (torn payload, bad CRC,
    unreadable manifest).  Internal signal for the fallback walk; surfaced
    only when the caller pinned the corrupt step explicitly."""


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 io_retries: int = 3, io_backoff: float = 0.05,
                 fault=None):
        self.dir = directory
        self.keep_n = keep_n
        self.io_retries = io_retries
        self.io_backoff = io_backoff
        self.fault = fault
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, blocking: bool = True):
        # never run two writers at once: a pending async save for the same
        # step would share (and race on) this save's tmp.<step> directory
        self.wait()
        leaves, treedef = _flatten(state)
        # device -> host now; non-native dtypes (bfloat16) are stored as
        # float32 (lossless upcast) and cast back on restore
        host_leaves = []
        for l in leaves:
            a = np.asarray(l)
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = np.asarray(jax.numpy.asarray(l).astype("float32"))
            host_leaves.append(a)
        if blocking:
            self._write(step, host_leaves, treedef)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves, treedef))
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves, treedef):
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        payload = os.path.join(tmp, "arrays.npz")
        np.savez(payload, **{f"a{i}": l for i, l in enumerate(host_leaves)})
        with open(payload, "rb") as f:
            raw = f.read()
        # length + CRC32 stamp: restore re-derives both from the bytes it
        # actually reads, so any truncation/bit-rot between here and there
        # is detected before the payload is parsed
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "treedef": str(treedef),
                       "payload_bytes": len(raw),
                       "crc32": zlib.crc32(raw)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        if self.fault is not None:
            self.fault.on_checkpoint_written(step, final)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read_payload_bytes(self, path: str) -> bytes:
        """Read the payload with bounded-backoff retry on transient IO
        errors (network-fs blips; injected via ``fault``)."""
        attempt = 0
        while True:
            try:
                if self.fault is not None:
                    self.fault.on_restore_read(path, attempt)
                with open(path, "rb") as f:
                    return f.read()
            except FileNotFoundError:
                raise  # not transient: the payload is gone, not slow
            except OSError as e:
                if attempt >= self.io_retries:
                    raise
                delay = self.io_backoff * (2 ** attempt)
                print(f"[ckpt] transient IO error reading {path} "
                      f"(attempt {attempt + 1}/{self.io_retries + 1}): "
                      f"{e}; retrying in {delay:.2f}s", flush=True)
                time.sleep(delay)
                attempt += 1

    def _load_validated(self, step: int):
        """Load + validate one checkpoint dir; raises CheckpointCorrupt on
        a torn payload / CRC mismatch / unreadable manifest."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        payload = os.path.join(d, "arrays.npz")
        manifest = os.path.join(d, "manifest.json")
        try:
            with open(manifest) as f:
                meta = json.load(f)
        except (OSError, ValueError) as e:
            raise CheckpointCorrupt(
                f"checkpoint step {step}: unreadable manifest ({e})")
        try:
            raw = self._read_payload_bytes(payload)
        except FileNotFoundError as e:
            raise CheckpointCorrupt(
                f"checkpoint step {step}: payload missing ({e})")
        want_len, want_crc = meta.get("payload_bytes"), meta.get("crc32")
        if want_len is not None and len(raw) != want_len:
            raise CheckpointCorrupt(
                f"checkpoint step {step}: torn payload — arrays.npz is "
                f"{len(raw)} bytes but the manifest stamped {want_len} "
                f"(truncated write)")
        if want_crc is not None and zlib.crc32(raw) != want_crc:
            raise CheckpointCorrupt(
                f"checkpoint step {step}: payload CRC mismatch "
                f"(bit-rot or partial overwrite)")
        import io
        try:
            return np.load(io.BytesIO(raw))
        except Exception as e:
            raise CheckpointCorrupt(
                f"checkpoint step {step}: payload unparseable ({e})")

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of `like`.  If `shardings` is given
        (same tree structure), arrays are device_put with those shardings —
        this is what makes restore topology-independent.

        With ``step=None`` (auto), checkpoints are tried newest-first:
        a candidate that fails payload validation (torn write) is skipped
        with a warning and the next older one is used — a crash never
        follows from a corrupt latest checkpoint.  An explicitly pinned
        ``step`` that fails validation raises instead."""
        pinned = step is not None
        candidates = [step] if pinned else list(reversed(self.all_steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        data, got_step, last_err = None, None, None
        for s in candidates:
            try:
                data = self._load_validated(s)
                got_step = s
                break
            except CheckpointCorrupt as e:
                last_err = e
                if pinned:
                    raise ValueError(str(e)) from e
                print(f"[ckpt] {e}; falling back to the previous "
                      f"checkpoint", flush=True)
        if data is None:
            raise FileNotFoundError(
                f"no valid checkpoint in {self.dir}: every candidate "
                f"failed validation (last: {last_err})")
        step = got_step
        leaves_like, treedef = _flatten(like)
        n = len(leaves_like)
        arrs = [data[f"a{i}"] for i in range(n)]
        # global shapes must match the template exactly — resharding restore
        # changes device placement, never array shape.  Without this check a
        # worker-stacked (N, ...) checkpoint (localsgd / chaos τ>=1)
        # restored under a different worker count would silently drop
        # workers' diverged state downstream instead of erroring here.  The
        # error names the offending leaf's tree path and both shapes so a
        # mismatch in a 100-leaf TrainState is diagnosable at a glance.
        keyed_leaves, _ = jax.tree_util.tree_flatten_with_path(like)
        for i, (a, l) in enumerate(zip(arrs, leaves_like)):
            if hasattr(l, "shape") and tuple(a.shape) != tuple(l.shape):
                leaf_path = jax.tree_util.keystr(keyed_leaves[i][0])
                raise ValueError(
                    f"checkpoint leaf {i} at {leaf_path}: checkpoint has "
                    f"shape {tuple(a.shape)} but the restore template "
                    f"expects {tuple(l.shape)}: the checkpoint was written "
                    f"under a different state layout (e.g. a worker-stacked "
                    f"localsgd / chaos staleness>=1 checkpoint resumed with "
                    f"a different --workers — stacked checkpoints pin the "
                    f"worker count; bsp and chaos staleness=0 checkpoints "
                    f"are worker-count-invariant)")
        # cast back through jnp: numpy lacks cast kernels for bf16 & friends
        arrs = [np.asarray(jax.numpy.asarray(a).astype(l.dtype))
                if hasattr(l, "dtype") and a.dtype != l.dtype else a
                for a, l in zip(arrs, leaves_like)]
        if shardings is not None:
            sh_leaves = treedef.flatten_up_to(shardings)
            arrs = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                    for a, s in zip(arrs, sh_leaves)]
        else:
            arrs = [jax.device_put(a) for a in arrs]
        return treedef.unflatten(arrs), step
