"""CHAOS gradient-synchronization strategies (the paper's core contribution,
adapted to SPMD — see DESIGN.md §2 for the Xeon-Phi -> TPU mapping).

Three modes, all usable by any architecture in the zoo:

``bsp``     Bulk-synchronous SGD (paper strategy B, per-minibatch): the
            gradient all-reduce sits on the critical path of every step.

``chaos``   Controlled-Hogwild: **staleness-τ exchange** (``SyncConfig.
            staleness``; semantics in ``train/sync.py``, DESIGN.md §5).
            On the worker-mesh path each worker applies its OWN gradient
            contribution instantly every step and folds peers' contributions
            in τ steps late (a τ-deep ring buffer) — the paper's "non-instant
            updates of weight parameters without significant delay" +
            "implicit synchronization in arbitrary order".  On the pjit path
            (one logical instance; peers are the implicit cross-replica
            reduction) the whole exchange is delayed τ steps,
                w_{t+1} = w_t - lr * mean_i g_i(w_{t-τ}-trajectory)
            so the reduction gates only the step *output* and XLA's
            latency-hiding scheduler overlaps it with backprop compute.
            τ=0 degenerates exactly to ``bsp`` (same strategy object).

``localsgd``  Paper strategy-C flavour: per-replica instances train on their
            own weights for K steps, then parameters are averaged.  This
            preserves CHAOS's "local updates are instant" property exactly
            (each worker trains on its freshest local weights) at the price
            of K-step weight divergence.  Implemented with an explicit
            replica axis via shard_map (replicas must fit per-device); the
            pjit train-step path runs the K-boundary average through
            ``localsgd_average`` (identity under plain jit, pmean over
            ``SyncConfig.axis_name`` under shard_map), keyed off the
            scan-carried step counter, so the mode composes with the
            superstep scan (DESIGN.md §3).

All modes keep the *semantics deterministic* — unlike racy shared-memory
Hogwild, the same run reproduces bit-exactly, which is how we check the
paper's Result 4 (accuracy parity) rigorously.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    mode: str = "bsp"            # any name in train/sync.py's registry
    local_steps: int = 8         # K for localsgd
    compress: bool = False       # bf16 gradient exchange w/ error feedback
    #: named mesh axis for the pjit-path localsgd parameter average; None
    #: (plain jit / single replica) makes the average an identity, but the
    #: K-step counter carry and the where-select still execute, so the
    #: superstep scan carry is exercised identically on 1 or N replicas.
    axis_name: Optional[str] = None
    #: chaos staleness τ, in steps: peers' gradient contributions fold into
    #: the update up to τ steps late (a τ-deep ring buffer in the scan
    #: carry).  τ=0 degenerates EXACTLY to bsp — the registry resolves
    #: chaos(τ=0) to the bsp strategy object, so bit-exactness is by
    #: construction (train/sync.py).  τ=1 on the pjit path reproduces the
    #: historical staleness-1 delayed exchange unchanged.
    staleness: int = 1
    #: per-bucket non-instant updates during backprop (the paper's §3 rule:
    #: apply dW_l as soon as layer l's gradient is produced, in reverse
    #: production order inside the step) — any model family via its
    #: ``bucket_spec()`` (CNN gets the true per-layer VJP tape), any
    #: optimizer via per-bucket state slicing, both execution paths
    #: (DESIGN.md §6).
    layerwise: bool = False
    #: dtype of the chaos(τ>=1) staleness-ring slots; ``None`` = param
    #: dtype.  ``"bfloat16"`` reuses the compression cast to halve the
    #: τ × params ring memory (exchange values are quantised on write and
    #: upcast to float32 on apply — the error is O(1 ulp bf16) per applied
    #: exchange, NOT accumulated: each slot is overwritten, not re-added).
    ring_dtype: Optional[str] = None
    #: overlap harness (DESIGN.md §8): injected per-byte latency, in
    #: nanoseconds/byte, charged to every *explicit* collective on the
    #: worker mesh (the ``all_gather`` in ``gathered_shard_mean``, the
    #: ``pmean`` in ``localsgd_average`` / the τ-ring boundary).  0.0 (the
    #: default) inserts NOTHING into the compiled graph, so every
    #: bit-exactness pin is untouched.  >0 models an interconnect of
    #: bandwidth 1/delay via deadline-sampling callbacks: the deadline is
    #: stamped when the collective's operand is ready and a gate sleeps
    #: only the *remainder* at the point the result is consumed — so on
    #: single-core CI, latency hidden behind compute shows up as a shorter
    #: residual sleep, independent of XLA thunk concurrency.
    collective_delay_ns_per_byte: float = 0.0
    #: layerwise worker-mesh schedule: fire each bucket's exchange the
    #: moment that layer's gradient is produced during backprop (the
    #: interleaved shard tape, DESIGN.md §8) instead of collecting the full
    #: stacked gradient tree first and then walking buckets.  Off by
    #: default: restructuring the backward into per-layer ``lax.map``
    #: bodies changes which canonical form XLA:CPU picks for each dw
    #: conv/matmul, so the tape's gradients agree with the batched path
    #: only to ~1 ulp (losses stay bit-equal) — the default keeps the
    #: collect-then-walk schedule that IS bit-exact to batched (the
    #: layerwise pins).  The interleaved schedule carries its own pins
    #: (run-to-run determinism, worker-count invariance, allclose vs
    #: collect) and is what ``benchmarks/overlap.py`` measures.  Ignored
    #: (falls back to collect-then-walk) when the model has no shard tape
    #: or the optimizer needs a whole-tree ``pre_apply`` (adamw's
    #: global-norm clip).
    interleave: bool = False

    def __post_init__(self):
        if self.staleness < 0:
            raise ValueError(
                f"staleness must be >= 0, got {self.staleness}")
        if self.collective_delay_ns_per_byte < 0:
            raise ValueError(
                "collective_delay_ns_per_byte must be >= 0, got "
                f"{self.collective_delay_ns_per_byte}")
        if self.ring_dtype is not None:
            jnp.dtype(self.ring_dtype)  # fail fast on an unknown dtype name


def zeros_like_f32(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


# ---------------------------------------------------------------------------
# Collective-latency injection (overlap harness, DESIGN.md §8).
#
# Forced host devices share one CPU core, so a busy-loop "slow collective"
# cannot show an overlap win.  Instead each injected collective is a
# *deadline pair*: a ``start`` callback samples ``now + bytes*delay`` when
# the collective's operand is ready (= issue time), and a ``gate`` callback
# at the consumer sleeps only the remainder.  Compute executed between issue
# and consume eats into the deadline, so hidden latency is measured by wall
# clock rather than by thunk concurrency.  Both callbacks return values that
# are folded into live data (a where-select tie and an add-exact-zero), so
# XLA cannot dead-code-eliminate or reorder them past their anchors; neither
# changes any value, and with delay == 0 none of this is ever inserted.
# ---------------------------------------------------------------------------
_EPOCH = time.monotonic()


def _now_ms() -> np.float32:
    return np.float32((time.monotonic() - _EPOCH) * 1e3)


def _start_cb(_anchor, delay_ms):
    return np.float32(float(_now_ms()) + float(delay_ms))


def _gate_cb(deadline, _anchor):
    rem = (float(deadline) - float(_now_ms())) * 1e-3
    if rem > 0:
        time.sleep(rem)
    return np.float32(0.0)


def _first_scalar(tree):
    return jnp.ravel(jax.tree.leaves(tree)[0])[0].astype(jnp.float32)


def tree_bytes(tree) -> int:
    """Static byte count of a (traced or concrete) pytree."""
    return sum(l.size * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def delay_start(anchor_tree, delay_ms):
    """Sample the deadline ``now + delay_ms`` (ms, f32 token) the moment the
    first leaf of ``anchor_tree`` is available.  ``delay_ms`` may be traced
    (e.g. scaled to zero off a localsgd boundary)."""
    return jax.pure_callback(
        _start_cb, jax.ShapeDtypeStruct((), np.float32),
        _first_scalar(anchor_tree), jnp.asarray(delay_ms, jnp.float32))


def delay_gate(tree, token, anchor_tree):
    """Sleep until ``token``'s deadline once ``anchor_tree`` is available,
    then pass ``tree`` through unchanged.  The gate's (always 0.0) output is
    added to the first leaf so the sleep cannot be eliminated; values are
    untouched (x + 0.0 == x)."""
    z = jax.pure_callback(
        _gate_cb, jax.ShapeDtypeStruct((), np.float32),
        token, _first_scalar(anchor_tree))
    leaves, treedef = jax.tree.flatten(tree)
    leaves = [leaves[0] + z.astype(leaves[0].dtype)] + leaves[1:]
    return jax.tree.unflatten(treedef, leaves)


def delay_tie(tree, token):
    """Order-tie: make ``tree`` depend on ``token`` without changing values
    (the select is never taken — tokens are finite).  Used to pin a start
    callback into the backward walk so XLA cannot hoist it to the end."""
    if token is None:
        return tree
    return jax.tree.map(
        lambda x: jnp.where(token < jnp.inf, x, jnp.zeros_like(x)), tree)


def inject_blocking_delay(tree, n_bytes, delay_ns_per_byte, scale=None):
    """Model a *synchronous* collective: deadline sampled when ``tree`` is
    ready, gate immediately after — the full ``n_bytes * delay`` charge lands
    on the critical path.  ``scale`` (traced, optional) multiplies the delay
    (e.g. 0 off a localsgd boundary)."""
    ms = n_bytes * delay_ns_per_byte * 1e-6
    if scale is not None:
        ms = jnp.asarray(ms, jnp.float32) * scale
    token = delay_start(tree, ms)
    return delay_gate(tree, token, tree)


# ---------------------------------------------------------------------------
# pjit path (production): synchronization behaviour lives in the pluggable
# strategy registry (train/sync.py); this wrapper is kept as the stable
# public name for sync-state construction.
# ---------------------------------------------------------------------------
def init_sync_state(sync: SyncConfig, params):
    from repro.train.sync import get_strategy  # local: avoid import cycle
    return get_strategy(sync).init_state(params)


def localsgd_average(sync: SyncConfig, params, step,
                     delay_ns_per_byte: float = 0.0):
    """Paper strategy-C boundary: every ``local_steps``-th step the replicas'
    parameters are averaged over ``sync.axis_name``.  The boundary derives
    from the (scan-carried, checkpointed) step counter — same arithmetic as
    the shard_map worker path — so no extra sync state is needed.  Under
    plain jit (axis_name=None, e.g. single logical device or implicit SPMD)
    the average is the identity but the select still runs.  Returns the new
    params.

    ``delay_ns_per_byte`` > 0 (overlap harness) charges the all-reduce
    2 × param-bytes synchronously, scaled to zero off the boundary — this is
    the blocking baseline the τ-ring boundary (train/sync.py) hides."""
    do_avg = ((step + 1) % sync.local_steps) == 0
    if sync.axis_name is not None:
        avg = jax.tree.map(lambda p: jax.lax.pmean(p, sync.axis_name), params)
        if delay_ns_per_byte > 0:
            # all-reduce effective bytes = 2 × tree bytes (roofline.py's
            # parse_collectives convention)
            avg = inject_blocking_delay(
                avg, 2 * tree_bytes(params), delay_ns_per_byte,
                scale=do_avg.astype(jnp.float32))
    else:
        avg = params
    return jax.tree.map(lambda p, a: jnp.where(do_avg, a, p), params, avg)


def compress_grads(grads, residual):
    """bf16 gradient exchange with float32 error feedback.

    The reduced tensor is bf16 (halves collective bytes vs f32); the
    quantisation error is carried and re-injected next step, so the long-run
    gradient sum is unbiased.
    """
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        q = acc.astype(jnp.bfloat16)
        return q, acc - q.astype(jnp.float32)
    flat = jax.tree.map(one, grads, residual)
    q = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return q, r


# ---------------------------------------------------------------------------
# shard_map path (per-replica instances; used by the CNN reproduction and
# localsgd).  Explicit collectives -> we control exactly when workers
# synchronize, mirroring the paper's worker model.
# ---------------------------------------------------------------------------
def gathered_shard_mean(tree, axis_name: str, n_workers: int,
                        n_shards: int, delay_ns_per_byte: float = 0.0):
    """Worker-count-invariant mean of stacked per-shard gradients.

    ``tree`` leaves are ``(n_shards / n_workers, ...)`` stacks of this
    worker's micro-shard gradients.  Instead of ``pmean`` (whose reduction
    tree depends on the worker count), every worker ``all_gather``s the
    full ``(n_shards, ...)`` stack — deterministically concatenated in
    axis-index order, which is exactly global shard order because worker w
    owns the contiguous shard range [w*S/N, (w+1)*S/N) — and then reduces
    it with one FIXED-shape ``sum`` over ``n_shards``.  The floating-point
    reduction is therefore identical for every N dividing ``n_shards``,
    which is what makes bsp/chaos updates (and their checkpoints) bit-exact
    across worker counts (tests/test_worker_scaling.py).

    ``delay_ns_per_byte`` > 0 (overlap harness) charges the gather its
    result bytes *synchronously* right here — the collect-then-walk /
    non-layerwise baseline.  The interleaved layerwise schedule instead
    passes 0 and places its own start/gate pair around the backward walk
    (train/step.py), so the same bytes land off the critical path."""
    if n_workers > 1:
        # gather in the *native* dtype: with per-shard bf16 compression the
        # collective moves half the bytes, and the fixed-shape reduction
        # below upcasts before summing
        tree = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axis_name, axis=0, tiled=True),
            tree)
        if delay_ns_per_byte > 0:
            # all-gather effective bytes = result bytes (roofline convention)
            tree = inject_blocking_delay(
                tree, tree_bytes(tree), delay_ns_per_byte)
    inv = 1.0 / n_shards
    # accumulate in f32 regardless of wire dtype (identity for f32 inputs,
    # so the uncompressed path's bit-exactness contract is untouched)
    return jax.tree.map(
        lambda x: jnp.sum(x.astype(jnp.float32), axis=0) * inv, tree)


def replicate_for_workers(tree, n: int):
    """Stack `n` copies along a leading replica axis."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                        tree)


def make_worker_step(loss_fn: Callable, lr_fn: Callable, sync: SyncConfig,
                     axis_name: str = "workers"):
    """LEGACY research harness — the production worker route is
    ``train/step.py::make_worker_superstep`` (superstep scan inside
    shard_map, optimizer/LR-schedule aware, worker-count-invariant bsp).
    Kept because its chaos flavour is the OTHER point in the staleness
    design space: local gradient applied instantly + remote gradients one
    step late, vs the production path's fully-stale global exchange
    (w_{t+1} = w_t - lr * mean_i g_i(w_{t-1})).  Exercised by
    tests/test_chaos.py for semantics comparison only.

    state = {params, prev_grad?, step}; each worker holds its OWN params
    (replica axis sharded over `axis_name`).  Sync behaviour:
      bsp      - psum every step, workers stay identical
      chaos    - apply own grad now + others' grads one step late
      localsgd - local SGD; average params every K steps
    """

    def step(state, batch):
        params = state["params"]
        lr = lr_fn(state["step"])
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        n = jax.lax.psum(1, axis_name)

        if sync.mode == "bsp":
            g = jax.lax.pmean(grads, axis_name)
            new_params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
            new_state = {**state, "params": new_params}
        elif sync.mode == "chaos":
            # Controlled Hogwild: local gradient lands instantly; remote
            # gradients arrive one step late (non-instant, no barrier on the
            # fresh local contribution).
            prev = state["prev_grad"]
            remote_stale = jax.tree.map(
                lambda s, sl: (jax.lax.psum(s, axis_name) - sl) / n,
                prev, prev)
            new_params = jax.tree.map(
                lambda p, gl, rs: p - lr * (gl / n + rs),
                params, grads, remote_stale)
            new_state = {**state, "params": new_params, "prev_grad": grads}
        elif sync.mode == "localsgd":
            local = jax.tree.map(lambda p, gg: p - lr * gg, params, grads)
            do_avg = (state["step"] + 1) % sync.local_steps == 0
            avg = jax.lax.pmean(local, axis_name)
            new_params = jax.tree.map(
                lambda l, a: jnp.where(do_avg, a, l), local, avg)
            new_state = {**state, "params": new_params}
        else:
            raise ValueError(sync.mode)
        new_state["step"] = state["step"] + 1
        metrics = {**metrics, "loss": loss}
        metrics = jax.lax.pmean(metrics, axis_name)
        return new_state, metrics

    return step


def worker_train_fn(loss_fn, lr_fn, sync: SyncConfig, mesh,
                    axis_name: str = "workers"):
    """Wrap the worker step in shard_map over a 1-D worker mesh.

    state trees carry a leading replica axis sharded over `axis_name`;
    batches carry a leading worker axis likewise.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    inner = make_worker_step(loss_fn, lr_fn, sync, axis_name)

    def whole(state, batch):
        def body(state_l, batch_l):
            state_l = jax.tree.map(lambda x: x[0], state_l)
            batch_l = jax.tree.map(lambda x: x[0], batch_l)
            new_state, metrics = inner(state_l, batch_l)
            return (jax.tree.map(lambda x: x[None], new_state), metrics)
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(axis_name), P()),
        )(state, batch)

    return jax.jit(whole)
