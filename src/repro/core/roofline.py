"""Roofline analysis from compiled dry-run artifacts (deliverable g).

TPU v5e targets (per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s/link

Terms (per device — the post-SPMD HLO module is the per-device program):
    compute term    = HLO_FLOPs / peak_FLOPs
    memory term     = HLO_bytes_accessed / HBM_bw
    collective term = effective_collective_bytes / link_bw
        where effective bytes = sum over collective ops of
        max(operand, result) local bytes, x2 for all-reduce (ring costs
        2(n-1)/n ~ 2 shard-volumes; others ~ 1).

collective bytes are parsed from the *optimized* HLO text since
cost_analysis does not expose them.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    """Bytes of the first shape literal in `text` (tuples: sum all)."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]
    effective_bytes: float
    ops: List[str]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVES}
    bytes_by_kind = {k: 0 for k in _COLLECTIVES}
    effective = 0.0
    ops = []
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-side instruction like:  %x = f32[..] all-reduce(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        kind = m.group(2)
        if kind.rstrip("-start").rstrip("-done") in _COLLECTIVES:
            kind = kind.replace("-start", "").replace("-done", "")
        if kind not in _COLLECTIVES:
            continue
        if "-done" in ls.split("(")[0]:
            continue  # avoid double counting start/done pairs
        result_b = _shape_bytes(m.group(1))
        # operand shapes are inside the parens
        inner = ls[ls.index("(") + 1:]
        operand_b = _shape_bytes(inner)
        b = max(result_b, operand_b)
        counts[kind] += 1
        bytes_by_kind[kind] += b
        effective += b * (2.0 if kind == "all-reduce" else 1.0)
        ops.append(ls[:160])
    return CollectiveStats(counts, bytes_by_kind, effective, ops)


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective: CollectiveStats
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float = 0.0
    per_device_hbm_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """max(terms)/sum(terms): 1.0 = perfectly bound by one roof (ideal
        overlap); the dominant term alone is the achievable lower bound."""
        s = self.compute_s + self.memory_s + self.collective_s
        return self.bound_s / s if s else 0.0

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self):
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "collective_bytes_per_dev": self.collective.total_bytes,
            "collective_effective_bytes": self.collective.effective_bytes,
            "collective_counts": self.collective.counts,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "roofline_fraction": self.roofline_fraction,
            "model_flops_total": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "per_device_hbm_bytes": self.per_device_hbm_bytes,
        }


def analyze(compiled, *, n_devices: int, model_flops_total: float = 0.0):
    """Build Roofline terms from a compiled executable.

    The partitioned HLO module is the per-device program, so cost_analysis
    FLOPs/bytes are per-device quantities already.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):    # older jax: one dict per program
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    mem = compiled.memory_analysis()
    hbm = 0.0
    if mem is not None:
        hbm = (getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))
    model_flops_dev = model_flops_total / n_devices if n_devices else 0.0
    return Roofline(
        flops=flops, bytes_accessed=bytes_acc, collective=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=coll.effective_bytes / ICI_BW,
        model_flops=model_flops_dev,
        per_device_hbm_bytes=hbm,
    )


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for training (N = active params),
    2*N per token for decode/prefill forward-only."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    if shape.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


# ---------------------------------------------------------------------------
# Fused-attention (Pallas flash kernel) accounting — §Perf hypothesis H1.
#
# The pure-jnp blockwise attention materialises score/prob matrices through
# HBM (XLA cannot fuse across the two dots, on CPU *or* TPU).  The Pallas
# kernel (kernels/flash_attention.py) keeps them in VMEM.  Because XLA cost
# analysis cannot see inside a pallas_call, the optimized cell's terms are
# the measured baseline minus this analytic overhead:
#
#   passes_fwd  = 4   (s write + s read + p write + p read)
#   passes_bwd  = 10  (s w/r, p w + 2 reads, dp w/r, ds w + 2 reads)
#   score_bytes = passes * B * Hq * Tq * Tk * 4 / n_dev
#
# and, for causal attention, the kernel skips ~half the kv blocks that the
# jnp version computes-and-masks:
#
#   skipped_flops ~= 0.5 * attn_dot_flops   (fwd: 2 dots, bwd: 5 dots)
# ---------------------------------------------------------------------------
def attention_call_shapes(cfg, shape):
    """Yield (Hq, Tq, Tk, D, Dv, causal, n_calls) per attention site."""
    T = shape.seq_len
    if cfg.family in ("dense", "moe"):
        yield (cfg.n_heads, T, T, cfg.d_head, cfg.d_head, True, cfg.n_layers)
    elif cfg.family == "mla":
        d = cfg.qk_nope_dim + cfg.qk_rope_dim
        yield (cfg.n_heads, T, T, d, cfg.v_head_dim, True, cfg.n_layers)
    elif cfg.family == "vlm":
        Tt = T + cfg.n_patches
        yield (cfg.n_heads, Tt, Tt, cfg.d_head, cfg.d_head, True,
               cfg.n_layers)
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        yield (cfg.n_heads, T, T, cfg.d_head, cfg.d_head, True, n_attn)
    elif cfg.family == "encdec":
        F = cfg.enc_frames
        yield (cfg.n_heads, F, F, cfg.d_head, cfg.d_head, False,
               cfg.n_enc_layers)               # encoder self
        yield (cfg.n_heads, T, T, cfg.d_head, cfg.d_head, True, cfg.n_layers)
        yield (cfg.n_heads, T, F, cfg.d_head, cfg.d_head, False,
               cfg.n_layers)                    # cross
    # ssm: no attention


def unfused_attention_overhead(cfg, shape, n_dev: int, train: bool):
    """Per-device (bytes, flops) that the Pallas flash kernel removes."""
    B = shape.global_batch
    passes = 4 + (10 if train else 0)
    dots = 2 + (5 if train else 0)
    bytes_total = 0.0
    flops_skip = 0.0
    for Hq, Tq, Tk, D, Dv, causal, n in attention_call_shapes(cfg, shape):
        elems = float(B) * Hq * Tq * Tk * n
        bytes_total += passes * elems * 4
        if causal:
            dot_flops = dots * 2.0 * elems * (D + Dv) / 2
            flops_skip += 0.5 * dot_flops
    return bytes_total / n_dev, flops_skip / n_dev
