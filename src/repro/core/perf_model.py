"""The paper's analytical performance-prediction model (Section 5.2).

Implements Listing 2 with the constants of Table 3 and the measured /
predicted memory contention of Table 4, and reproduces:
  - Figures 11-13 (predicted vs measured execution times),
  - Table 8 (predicted minutes for 480..3840 threads),
  - Table 9 (scaling epochs/images at 240/480 threads),
  - the Result-3 speedup numbers (via T(1)/T(p)).

All quantities are in the paper's own units (operations, Hz, seconds).
"""
from __future__ import annotations

import dataclasses
import math

# hardware constants (Table 3)
CLOCK_HZ = 1.238e9
OPERATION_FACTOR = 15
CORES = 61
HW_THREADS = 244

# per-architecture operation counts (Table 3, 'Calculated')
OPS = {
    "small": dict(fprop=58_000, bprop=524_000, prep=1e9),
    "medium": dict(fprop=559_000, bprop=6_119_000, prep=1e10),
    "large": dict(fprop=5_349_000, bprop=73_178_000, prep=1e11),
}

# measured per-image times in ms (Table 3) — used for "prediction b"
MEASURED_MS = {
    "small": dict(fprop=1.45, bprop=5.3, prep_s=12.56),
    "medium": dict(fprop=12.55, bprop=69.73, prep_s=12.7),
    "large": dict(fprop=148.88, bprop=859.19, prep_s=13.5),
}

# memory contention per (threads, arch) — Table 4 (* = predicted rows)
MEM_CONTENTION = {
    "small": {1: 7.10e-6, 15: 6.40e-4, 30: 1.36e-3, 60: 3.07e-3,
              120: 6.76e-3, 180: 9.95e-3, 240: 1.40e-2, 480: 2.78e-2,
              960: 5.60e-2, 1920: 1.12e-1, 3840: 2.25e-1},
    "medium": {1: 1.56e-4, 15: 2.00e-3, 30: 3.97e-3, 60: 8.03e-3,
               120: 1.65e-2, 180: 2.50e-2, 240: 3.83e-2, 480: 7.31e-2,
               960: 1.47e-1, 1920: 2.95e-1, 3840: 5.91e-1},
    "large": {1: 8.83e-4, 15: 8.75e-3, 30: 1.67e-2, 60: 3.22e-2,
              120: 6.74e-2, 180: 1.00e-1, 240: 1.38e-1, 480: 2.73e-1,
              960: 5.46e-1, 1920: 1.09, 3840: 2.19},
}

EPOCHS = {"small": 70, "medium": 70, "large": 15}
N_TRAIN = 60_000
N_TEST = 10_000


def dense_lm_ops(cfg, seq: int) -> dict:
    """Per-sample (sequence) operation counts for a dense LM in the
    paper's Table-3 unit convention (one multiply-accumulate-ish
    "operation"): 2 ops per weight per token for the matmuls, plus the
    causal-half ``4 * L * H * dh * T^2 / 2`` attention score/value term
    the weights don't account for.  ``bprop`` is the usual ~2x fprop
    (grad wrt activations + grad wrt weights)."""
    d, dh = cfg.d_model, cfg.d_head
    per_layer = (d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh
                 + cfg.n_heads * dh * d + 3 * d * cfg.d_ff)
    mats = cfg.n_layers * per_layer + d * cfg.padded_vocab
    attn = 4 * cfg.n_layers * cfg.n_heads * dh * seq * seq // 2
    fprop = 2 * seq * mats + attn
    return dict(fprop=float(fprop), bprop=float(2 * fprop))


def register_arch(key: str, *, fprop: float, bprop: float,
                  prep: float = 1e9, epochs: int = 70) -> None:
    """Register a non-Table-2 architecture (e.g. the dense-LM bench net)
    so ``predict_time``/``predict_speedup`` cover it.  ``fprop``/``bprop``
    are per-sample operation counts in the paper's units; the memory-
    contention column is the small-CNN Table-4 column scaled by the
    total-ops ratio (contention in the paper's model is linear in the
    per-sample memory traffic, which tracks operation count).  Idempotent:
    re-registering an existing key is a no-op, the Table-2 keys cannot be
    overwritten."""
    if key in OPS:
        return
    ratio = ((fprop + bprop)
             / (OPS["small"]["fprop"] + OPS["small"]["bprop"]))
    OPS[key] = dict(fprop=float(fprop), bprop=float(bprop), prep=prep)
    MEM_CONTENTION[key] = {p: c * ratio
                           for p, c in MEM_CONTENTION["small"].items()}
    EPOCHS[key] = epochs


def cpi(p: int) -> float:
    """Best theoretical CPI per thread (Table 3): 1-2 thr/core: 1;
    3 thr/core: 1.5; 4 thr/core: 2."""
    tpc = math.ceil(p / CORES) if p <= HW_THREADS else 4
    if tpc <= 2:
        return 1.0
    if tpc == 3:
        return 1.5
    return 2.0


def memory_contention(arch: str, p: int) -> float:
    table = MEM_CONTENTION[arch]
    if p in table:
        return table[p]
    # linear in p (matches the paper's predicted rows: 480..3840 = 240 row
    # scaled by p/240)
    anchor_p = 240 if p > 240 else max(k for k in table if k <= p)
    return table[anchor_p] * p / anchor_p


def t_mem(arch: str, ep: int, i: int, p: int) -> float:
    return memory_contention(arch, p) * ep * i / p


def predict_time(arch: str, p: int, *, i: int = N_TRAIN, it: int = N_TEST,
                 ep: int | None = None) -> float:
    """Total predicted execution time in seconds (Listing 2, prediction a)."""
    ep = EPOCHS[arch] if ep is None else ep
    ops = OPS[arch]
    s = CLOCK_HZ
    fprop, bprop, prep = ops["fprop"], ops["bprop"], ops["prep"]
    seq = (prep + 4 * i + 2 * it + 10 * ep) / s
    train = ((fprop + bprop) / s) * (i / p) * ep
    valid = (fprop / s) * (i / p) * ep
    test = (fprop / s) * (it / p) * ep
    # CPI penalises only the *parallel* phases (sequential preparation runs a
    # single thread per core => CPI 1).  This interpretation reproduces the
    # paper's Table 8 exactly for the large CNN (92.9/60.8/44.8/36.8 min).
    t_comp = (seq + (train + valid + test) * cpi(p)) * OPERATION_FACTOR
    return t_comp + t_mem(arch, ep, i, p)


def predict_speedup(arch: str, p: int, baseline_p: int = 1) -> float:
    return predict_time(arch, baseline_p) / predict_time(arch, p)


def table8() -> dict:
    """Predicted minutes for 480..3840 threads (paper Table 8)."""
    return {arch: {p: predict_time(arch, p) / 60
                   for p in (480, 960, 1920, 3840)}
            for arch in ("small", "medium", "large")}


def table9() -> dict:
    """Scaling epochs/images for 240 & 480 threads, small CNN (Table 9)."""
    out = {}
    for p in (240, 480):
        for mult in (1, 2, 4):
            for ep in (70, 140, 280, 560):
                key = (p, 60_000 * mult, ep)
                out[key] = predict_time("small", p, i=60_000 * mult,
                                        it=10_000 * mult, ep=ep) / 60
    return out


# paper's Table 8 reference values (minutes), for regression tests
PAPER_TABLE8 = {
    "small": {480: 6.6, 960: 5.4, 1920: 4.9, 3840: 4.6},
    "medium": {480: 36.8, 960: 23.9, 1920: 17.4, 3840: 14.2},
    "large": {480: 92.9, 960: 60.8, 1920: 44.8, 3840: 36.8},
}

# paper Table 9 anchors (240 threads, small): minutes
PAPER_TABLE9_240 = {(70, 60_000): 8.9, (140, 60_000): 17.6,
                    (280, 60_000): 35.0, (560, 60_000): 69.7,
                    (70, 120_000): 17.6, (70, 240_000): 35.0}
