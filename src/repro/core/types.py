"""Configuration dataclasses shared by the whole framework.

One ``ArchConfig`` describes any architecture in the zoo (dense GQA
transformer, MLA, MoE, Mamba2 hybrid, RWKV6, enc-dec, VLM backbone, and the
paper's CNNs).  One ``ShapeConfig`` describes an input-shape cell
(train / prefill / decode / long-context-decode).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | mla | moe | hybrid | ssm | encdec | vlm | cnn

    # transformer backbone
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0          # hybrid: one shared attn block every N layers

    # enc-dec
    n_enc_layers: int = 0
    enc_frames: int = 1500       # stub audio frontend output length

    # vlm
    n_patches: int = 0           # stub vision frontend output length

    # CNN (paper Table 2): tuples of layer specs
    # conv: ("conv", maps, kernel) / pool: ("pool", kernel) / fc: ("fc", n)
    cnn_layers: Tuple[tuple, ...] = ()
    cnn_input: Tuple[int, int] = (29, 29)
    n_classes: int = 10

    # training knobs
    use_kernel: bool = False     # route hot path through Pallas kernels
    micro_batches: int = 1       # gradient-accumulation steps per batch
    #: LM layer-stack chunking (DESIGN.md §10): split the stacked ``layers``
    #: leaf into ``n_layers / layer_chunk`` per-chunk param keys so
    #: ``bucket_spec()`` exposes embed -> per-chunk -> head buckets.  0 (and
    #: ``n_layers``) keep today's single-stack scan layout; 1 is the fully
    #: unrolled layout; must divide ``n_layers``.
    layer_chunk: int = 0
    param_dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    lr_schedule: str = "constant"  # constant | decay (paper) | wsd (minicpm)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        """Whether the arch supports autoregressive decode shapes."""
        return self.family != "cnn"

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs and sanity checks)."""
        if self.family == "cnn":
            from repro.models import cnn  # local import to avoid cycle
            return cnn.param_count(self)
        d, L, ff, V = self.d_model, self.n_layers, self.d_ff, self.padded_vocab
        dh = self.d_head
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        per_layer = 0
        if self.family == "mla":
            r_q, r_kv = self.q_lora_rank, self.kv_lora_rank
            qk = self.qk_nope_dim + self.qk_rope_dim
            per_layer += d * r_q + r_q * self.n_heads * qk
            per_layer += d * (r_kv + self.qk_rope_dim)
            per_layer += r_kv * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            per_layer += self.n_heads * self.v_head_dim * d
        elif self.family in ("dense", "moe", "vlm", "encdec"):
            per_layer += d * (self.n_heads + 2 * self.n_kv_heads) * dh
            per_layer += self.n_heads * dh * d
        if self.family == "moe":
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
        elif self.family in ("dense", "mla", "vlm", "encdec"):
            per_layer += 3 * d * ff
        if self.family == "hybrid":
            din = d * self.ssm_expand
            H = max(din // 64, 1)
            # per-layer mamba block: in_proj + conv + out_proj
            mamba = (d * (2 * din + 2 * self.ssm_state + H)
                     + self.ssm_conv * din + din * d)
            # shared attention + shared MLP: ONE set of weights, reused
            attn = (d * (self.n_heads + 2 * self.n_kv_heads) * dh
                    + self.n_heads * dh * d)
            per_layer = 0
            n += L * mamba + attn + 3 * d * ff
        if self.family == "ssm":  # rwkv6
            per_layer = d * d * 4 + d * ff * 2 + d * 64 * 6  # tm/td lora-ish
        n += L * per_layer
        if self.family == "encdec":
            enc_layer = d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d + 3 * d * ff
            # cross attention in decoder
            n += self.n_enc_layers * enc_layer + L * (d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d)
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        dh = self.d_head
        n = 2 * self.padded_vocab * d
        per_layer = d * (self.n_heads + 2 * self.n_kv_heads) * dh
        per_layer += self.n_heads * dh * d
        per_layer += d * self.n_experts
        per_layer += self.top_k * 3 * d * self.moe_d_ff
        return int(n + L * per_layer)


@dataclasses.dataclass(frozen=True)
class ParamBucket:
    """One ordered, disjoint slice of a model's parameter tree (DESIGN.md §6).

    Every model family exposes ``bucket_spec()`` (``models/api.py``): an
    ordered tuple of buckets whose ``keys`` — top-level param-tree keys —
    form an exact disjoint cover of the tree (property-tested for every
    registered family).  Buckets are the granularity at which gradients are
    exchanged (``SyncStrategy.bucket_exchange``), compressed (per-bucket
    error-feedback residual slices), and applied (per-bucket optimizer-state
    slicing, ``Optimizer.slice_state``): the paper's per-layer non-instant
    update rule walks buckets in reverse-production order, so each bucket's
    exchange + update chains to that bucket's gradient production instead of
    a whole-tree barrier.

    ``index`` is the bucket's position in *production* (forward) order; the
    gradient tape yields buckets at ``index`` descending.
    """
    name: str
    keys: Tuple[str, ...]
    index: int

    def view(self, tree: dict) -> dict:
        """This bucket's slice of a params-shaped (top-level-keyed) tree."""
        return {k: tree[k] for k in self.keys}


@dataclasses.dataclass(frozen=True)
class WorkerConfig:
    """CHAOS worker model: N per-device worker instances over a named mesh
    axis (the paper's Phi threads -> forced host devices, DESIGN.md §4).

    ``logical_shards`` decouples the *semantic* batch decomposition from the
    *physical* worker count: the global batch is always split into
    ``logical_shards`` fixed micro-shards whose gradients are combined with
    a fixed-shape reduction, so any ``workers`` dividing ``logical_shards``
    computes bit-identical bsp/chaos updates (worker-count-invariant
    checkpoints; tests/test_worker_scaling.py)."""
    workers: int = 1
    axis: str = "workers"
    logical_shards: int = 8

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.logical_shards % self.workers != 0:
            raise ValueError(
                f"workers={self.workers} must divide "
                f"logical_shards={self.logical_shards} so every worker "
                f"owns an equal number of micro-shards")

    @property
    def shards_per_worker(self) -> int:
        return self.logical_shards // self.workers

    def resized(self, workers: int) -> "WorkerConfig":
        """This config at a new worker count (elastic resize, DESIGN.md §7).
        ``logical_shards`` is deliberately carried over unchanged — it is
        the invariant that keeps bsp/chaos gradients bit-exact across the
        membership change; validation re-runs in ``__post_init__``."""
        return dataclasses.replace(self, workers=workers)

    def clamp_workers(self, requested: int) -> int:
        """Largest valid worker count <= ``requested`` (>= 1): elastic
        membership targets (a kill leaving N-1 workers, a grow event) must
        still divide ``logical_shards``, so e.g. losing one of 4 workers
        with 8 logical shards lands on N'=3 -> 2."""
        for n in range(min(requested, self.logical_shards), 0, -1):
            if self.logical_shards % n == 0:
                return n
        return 1

    def validate_batch(self, batch: int) -> None:
        if batch % self.logical_shards != 0:
            raise ValueError(
                f"global batch {batch} must be divisible by "
                f"logical_shards={self.logical_shards} "
                f"(per-shard batch must be uniform for the fixed-shape "
                f"worker reduction)")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
