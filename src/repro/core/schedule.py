"""Learning-rate schedules.

- ``decay``: the paper's schedule — eta0 = 0.001 multiplied by 0.9 each epoch.
- ``wsd``: Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395).
- ``constant`` / ``cosine``: standard baselines.
"""
from __future__ import annotations

import jax.numpy as jnp


def make_lr_fn(kind: str, base_lr: float = 1e-3, *, steps_per_epoch: int = 1,
               total_steps: int = 10_000, warmup: int = 100,
               decay_frac: float = 0.1, decay_factor: float = 0.9):
    if kind == "constant":
        return lambda step: jnp.asarray(base_lr, jnp.float32)

    if kind == "decay":  # the paper's: eta0 * factor^epoch
        def fn(step):
            epoch = step // steps_per_epoch
            return base_lr * jnp.power(decay_factor, epoch).astype(jnp.float32)
        return fn

    if kind == "wsd":
        stable_end = int(total_steps * (1 - decay_frac))
        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            warm = base_lr * jnp.minimum((step + 1) / max(warmup, 1), 1.0)
            decay_t = jnp.clip((step - stable_end) /
                               max(total_steps - stable_end, 1), 0.0, 1.0)
            dec = base_lr * jnp.exp(jnp.log(0.1) * decay_t)  # 10x drop
            return jnp.where(step < stable_end, warm, dec)
        return fn

    if kind == "cosine":
        def fn(step):
            step = jnp.asarray(step, jnp.float32)
            warm = jnp.minimum(step / max(warmup, 1), 1.0)
            prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1),
                            0.0, 1.0)
            return base_lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return fn

    raise ValueError(kind)
