"""Pallas max-pool kernels so the CNN hot path (conv -> tanh -> pool) runs
end-to-end through Pallas in both directions (DESIGN.md §Kernels).

VALID pooling with stride == window (the paper's nets): output spatial dims
floor to ``H // k``; trailing rows/cols that don't fill a window are cropped
(forward) and receive zero gradient (backward), matching
``lax.reduce_window``.

Tie semantics in the backward: XLA's select-and-scatter routes the whole
gradient to the first maximum; this kernel splits it evenly across tied
maxima.  Both are valid subgradients.  They agree whenever the window max
is unique — true almost surely for well-scaled conv+tanh activations, but
NOT when tanh saturates (fp32 tanh returns exactly +/-1.0 for |z| >~ 8.6,
so saturated windows do tie); expect a bounded gradient divergence from
the XLA path in that regime, not an error.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.conv2d import _divisor_block, record_launch


def _maxpool_fwd_kernel(x_ref, o_ref, *, k: int, Ho: int, Wo: int):
    x = x_ref[...]                       # (bb, H, W, C)
    bb, C = x.shape[0], x.shape[3]
    xc = x[:, :Ho * k, :Wo * k, :].reshape(bb, Ho, k, Wo, k, C)
    o_ref[...] = jnp.max(xc, axis=(2, 4)).astype(o_ref.dtype)


def maxpool2d_fwd(x, k: int, *, batch_block: int = 8,
                  interpret: bool = True):
    B, H, W, C = x.shape
    Ho, Wo = H // k, W // k
    bb = _divisor_block(B, batch_block)
    record_launch("maxpool2d_fwd")
    return pl.pallas_call(
        functools.partial(_maxpool_fwd_kernel, k=k, Ho=Ho, Wo=Wo),
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, H, W, C), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, Ho, Wo, C), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, C), x.dtype),
        interpret=interpret,
    )(x)


def _maxpool_bwd_kernel(x_ref, y_ref, dy_ref, dx_ref, *, k: int, Ho: int,
                        Wo: int):
    x = x_ref[...]                       # (bb, H, W, C)
    bb, H, W, C = x.shape
    xc = x[:, :Ho * k, :Wo * k, :].reshape(bb, Ho, k, Wo, k, C)
    y = y_ref[...][:, :, None, :, None, :]        # (bb, Ho, 1, Wo, 1, C)
    mask = (xc == y).astype(jnp.float32)
    ties = jnp.sum(mask, axis=(2, 4), keepdims=True)
    dxc = mask * (dy_ref[...][:, :, None, :, None, :].astype(jnp.float32)
                  / ties)
    dxc = dxc.reshape(bb, Ho * k, Wo * k, C)
    dx_ref[...] = jnp.pad(
        dxc, ((0, 0), (0, H - Ho * k), (0, W - Wo * k), (0, 0))
    ).astype(dx_ref.dtype)


def maxpool2d_bwd(x, y, dy, k: int, *, batch_block: int = 8,
                  interpret: bool = True):
    """dx for maxpool2d_fwd; one pallas_call, gradient split across ties."""
    B, H, W, C = x.shape
    Ho, Wo = H // k, W // k
    bb = _divisor_block(B, batch_block)
    record_launch("maxpool2d_bwd")
    return pl.pallas_call(
        functools.partial(_maxpool_bwd_kernel, k=k, Ho=Ho, Wo=Wo),
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, H, W, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((bb, Ho, Wo, C), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((bb, Ho, Wo, C), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, H, W, C), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, C), x.dtype),
        interpret=interpret,
    )(x, y, dy)
