"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked form).

The wkv state-passing recurrence is the compute hot-spot of the
attention-free archs (rwkv6-1.6b), which the flash-attention kernel does
not cover.  Same chunked math as ``models/rwkv6.wkv_chunked`` (the oracle),
but the per-chunk (Q,Q) score tile, the (Q,D) rescale tensors, and the
(D,D) running state all stay in VMEM:

  grid = (B, H, T/Q)      -- chunk index innermost, sequential on TPU
  scratch: S (D, D) f32   -- the recurrence state, carried across chunks
  per chunk:
    seg   = cumsum(log w)                        (Q, D)
    y     = tril(-1)[ (r e^{seg-lw}) (k e^{-seg})^T ] v   intra-chunk
          + ((r*u*k).1) * v                      bonus diagonal
          + (r e^{seg-lw}) S                     inter-chunk
    S     = diag(e^{seg_last}) S + (k e^{seg_last - seg})^T v

Q = D = 64 tiles keep everything MXU-aligned and well under VMEM.
Decay logs are clamped upstream (models/rwkv6._time_mix) so e^{-seg} is
finite in f32 for Q = 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_sc, *, Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_sc[...] = jnp.zeros_like(s_sc)

    r = r_ref[0, 0].astype(jnp.float32)   # (Q, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)      # (D,)

    lw = jnp.log(jnp.clip(w, 1e-12))
    seg = jnp.cumsum(lw, axis=0)          # (Q, D)
    ri = r * jnp.exp(seg - lw)            # e^{seg_{i-1}}
    kj = k * jnp.exp(-seg)

    att = jax.lax.dot_general(ri, kj, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    att = jnp.where(jj < ii, att, 0.0)    # strictly causal within chunk

    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)
    y = y + bonus * v
    y = y + jax.lax.dot_general(ri, s_sc[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    wj = jnp.exp(seg[-1][None, :] - seg)  # (Q, D)
    s_new = (s_sc[...] * jnp.exp(seg[-1])[:, None]
             + jax.lax.dot_general(k * wj, v, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_sc[...] = s_new
    o_ref[0, 0] = y.astype(o_ref.dtype)


def wkv6_chunked(r, k, v, w, u, *, chunk: int = 64, interpret: bool = True):
    """r, k, v, w: (B, T, H, D); w = decay in (0,1); u: (H, D).
    Returns y: (B, T, H, D).  T must be a multiple of `chunk`."""
    B, T, H, D = r.shape
    assert T % chunk == 0, (T, chunk)
    nC = T // chunk

    def bhtd(x):  # (B,T,H,D) -> (B,H,T,D)
        return x.transpose(0, 2, 1, 3)

    kern = functools.partial(_wkv_kernel, Q=chunk)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nC),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, D), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, D), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, D), r.dtype),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(bhtd(r), bhtd(k), bhtd(v), bhtd(w), u)
    return out.transpose(0, 2, 1, 3)
