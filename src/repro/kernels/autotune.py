"""Block-size autotuner for the Pallas kernels (DESIGN.md §Kernels).

The paper hand-picks its vectorisation widths for one machine (Listing 1 is
written for 512-bit Xeon-Phi SIMD); the TPU analogue — how many batch rows,
output rows, and output channels each grid step keeps in VMEM — is
shape-dependent, so we search instead of hard-coding.

Two-phase design, because timing is impossible under ``jit`` tracing:

* ``tune_*`` entry points (called by ``benchmarks/run.py --only kernels``
  and tests) measure every candidate on real arrays, pick the fastest, and
  persist the result to an on-disk JSON cache keyed by
  ``op|shapes|dtype|backend|interpret``.
* ``get_conv_fwd_config`` / ``get_conv_bwd_config`` (called from
  ``kernels/ops.py`` on the training hot path, possibly inside a trace)
  return the cached winner when present, else a VMEM-budget heuristic.

Candidates are divisor block sizes pruned by a VMEM-footprint estimate, and
the hard-coded ``batch_block=8`` whole-map baseline is ALWAYS in the
candidate set, so the tuned pick is never slower than the baseline on the
measurements it was chosen from.

Cache format (one JSON object)::

    {"<key>": {"config": {"batch_block": 8, "row_block": 13, ...},
               "us": 123.4,
               "candidates": {"<config-json>": us, ...},
               "timestamp": 1690000000.0}, ...}
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import conv2d as K
from repro.kernels import fc as FCK
from repro.obs.trace import span as _obs_span


def _traced(fn):
    """Wrap a tune entry point in an ``autotune`` obs span (DESIGN.md §11)
    so kernel-tuning time lands on the trace timeline; no-op without an
    installed tracer."""
    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with _obs_span("autotune", target=fn.__name__):
            return fn(*args, **kwargs)
    return wrapped

_MEM: dict[str, dict] = {}
# one-shot disk snapshot so cache misses on the eager hot path don't
# re-open the JSON file per conv call; reloaded when the path changes
_DISK: dict = {"path": None, "data": {}}

#: VMEM is ~16 MB/core; leave headroom for double buffering + the compiler.
VMEM_BUDGET_BYTES = int(os.environ.get("REPRO_VMEM_BUDGET", 12 * 2 ** 20))

BASELINE = {"batch_block": 8, "row_block": None, "cout_block": None}
BWD_BASELINE = {"batch_block": 8, "row_block": None}
FC_BASELINE = {"batch_block": 8, "dout_block": None}
FC_BWD_BASELINE = {"batch_block": 8}
FLASH_BASELINE = {"block_q": 512, "block_k": 512}


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_disk(entries: dict) -> None:
    """Concurrent-writer-safe persist: re-merge against the file, write to a
    tmp file UNIQUE to this process (mkstemp), then atomically rename.  Two
    processes tuning the same net may each win some last-writer races on
    individual keys, but the cache file itself can never be torn/corrupt —
    a shared ``path + ".tmp"`` name would let two writers interleave bytes
    in one tmp file before the rename (tests/test_autotune_cache.py)."""
    import tempfile

    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    merged = _load_disk()
    merged.update(entries)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".autotune.", suffix=".tmp")
    try:
        # mkstemp creates 0600 scratch files; restore umask-based perms so
        # a shared cache path stays readable to other users/CI stages like
        # the plain open() it replaced
        umask = os.umask(0)
        os.umask(umask)
        os.fchmod(fd, 0o666 & ~umask)
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def key_for(op: str, shapes, dtype, *, interpret: bool,
            variant: str = "plain") -> str:
    """``variant`` distinguishes kernel flavours with different VMEM /
    compute profiles under the same shapes: the bias+tanh forward epilogue
    and the dtanh-fused backward (which carries an extra y slab)."""
    shp = "x".join("_".join(map(str, s)) for s in shapes)
    return (f"{op}|{variant}|{shp}|{jnp.dtype(dtype).name}"
            f"|{jax.default_backend()}|interp={int(interpret)}")


def lookup(key: str) -> dict | None:
    if key in _MEM:
        return _MEM[key]
    if _DISK["path"] != cache_path():
        _DISK["path"] = cache_path()
        _DISK["data"] = _load_disk()
    entry = _DISK["data"].get(key)
    if entry is not None:
        _MEM[key] = entry
    return entry


def record(key: str, config: dict, us: float, candidates: dict,
           iters: int = 1) -> dict:
    """Persist a tuning result.  A result measured with fewer timing
    iterations never overwrites one measured with more (so a --quick
    iters=1 run can't clobber a careful iters=3 tune with noise)."""
    existing = lookup(key)
    if existing is not None and existing.get("iters", 1) > iters:
        return existing
    entry = {"config": config, "us": us, "candidates": candidates,
             "iters": iters, "timestamp": time.time()}
    _MEM[key] = entry
    if _DISK["path"] == cache_path():
        _DISK["data"][key] = entry
    _save_disk({key: entry})
    return entry


def clear_memory_cache() -> None:
    _MEM.clear()
    _DISK["path"], _DISK["data"] = None, {}


# ---------------------------------------------------------------------------
# Candidate generation + VMEM footprint estimates
# ---------------------------------------------------------------------------
def _divisors(n: int, cap: int | None = None) -> list[int]:
    cap = n if cap is None else min(cap, n)
    return [d for d in range(1, cap + 1) if n % d == 0]


def conv_fwd_vmem_bytes(cfg, x_shape, w_shape, itemsize: int = 4) -> int:
    """Bytes resident per grid step: x slab + weight block + out block +
    the fp32 accumulator."""
    B, H, W, Cin = x_shape
    Kk, _, _, Cout = w_shape
    Ho, Wo = H - Kk + 1, W - Kk + 1
    bb = K._divisor_block(B, cfg["batch_block"])
    rb = K._divisor_block(Ho, cfg["row_block"])
    cb = K._divisor_block(Cout, cfg["cout_block"])
    return (bb * (rb + Kk - 1) * W * Cin * itemsize
            + Kk * Kk * Cin * cb * itemsize
            + bb * rb * Wo * cb * itemsize
            + bb * rb * Wo * cb * 4)


def conv_bwd_vmem_bytes(cfg, x_shape, w_shape, itemsize: int = 4,
                        fused_tanh: bool = True) -> int:
    B, H, W, Cin = x_shape
    Kk, _, _, Cout = w_shape
    Wo = W - Kk + 1
    bb = K._divisor_block(B, cfg["batch_block"])
    rb = K._divisor_block(H, cfg["row_block"])
    slab = bb * (rb + Kk - 1) * (Wo + 2 * (Kk - 1)) * Cout * itemsize
    return (bb * (rb + Kk - 1) * W * Cin * itemsize      # x slab
            + slab * (2 if fused_tanh else 1)            # dy (+ y) slabs
            + Kk * Kk * Cin * Cout * (itemsize + 4)      # w + dw scratch
            + bb * rb * W * Cin * itemsize)              # dx block


def conv_fwd_candidates(x_shape, w_shape, itemsize: int = 4) -> list[dict]:
    B, H, W, Cin = x_shape
    Kk, _, _, Cout = w_shape
    Ho = H - Kk + 1
    cands = [dict(BASELINE)]
    for bb in _divisors(B, 16):
        for rb in _divisors(Ho):
            if rb < Kk and rb != Ho:      # halo would dominate the slab
                continue
            for cb in _divisors(Cout, 128):
                if cb % 8 and cb != Cout:  # keep lane-friendly channel blocks
                    continue
                cfg = {"batch_block": bb, "row_block": rb, "cout_block": cb}
                if conv_fwd_vmem_bytes(cfg, x_shape, w_shape,
                                       itemsize) <= VMEM_BUDGET_BYTES:
                    cands.append(cfg)
    return _dedup(cands)


def conv_bwd_candidates(x_shape, w_shape, itemsize: int = 4) -> list[dict]:
    B, H, W, Cin = x_shape
    Kk = w_shape[0]
    cands = [dict(BWD_BASELINE)]
    for bb in _divisors(B, 16):
        for rb in _divisors(H):
            if rb < Kk and rb != H:
                continue
            cfg = {"batch_block": bb, "row_block": rb}
            if conv_bwd_vmem_bytes(cfg, x_shape, w_shape,
                                   itemsize) <= VMEM_BUDGET_BYTES:
                cands.append(cfg)
    return _dedup(cands)


def fc_fwd_vmem_bytes(cfg, x_shape, w_shape, itemsize: int = 4) -> int:
    """Bytes per grid step: x row block + w column block + bias block +
    the output tile and its fp32 accumulator."""
    B, Din = x_shape
    _, Dout = w_shape
    bb = K._divisor_block(B, cfg["batch_block"])
    db = K._divisor_block(Dout, cfg["dout_block"])
    return (bb * Din * itemsize + Din * db * itemsize + db * itemsize
            + bb * db * (itemsize + 4))


def fc_bwd_vmem_bytes(cfg, x_shape, w_shape, itemsize: int = 4,
                      fused_tanh: bool = True) -> int:
    B, Din = x_shape
    _, Dout = w_shape
    bb = K._divisor_block(B, cfg["batch_block"])
    return (bb * Din * itemsize                      # x block
            + bb * Dout * itemsize * (2 if fused_tanh else 1)  # dy (+ y)
            + Din * Dout * (itemsize + 4)            # w + dw scratch
            + Dout * 4                               # db scratch
            + bb * Din * itemsize)                   # dx block


def fc_fwd_candidates(x_shape, w_shape, itemsize: int = 4) -> list[dict]:
    B, _ = x_shape
    _, Dout = w_shape
    cands = [dict(FC_BASELINE)]
    for bb in _divisors(B, 64):
        for db in _divisors(Dout, 512):
            if db % 8 and db != Dout:  # keep lane-friendly column blocks
                continue
            cfg = {"batch_block": bb, "dout_block": db}
            if fc_fwd_vmem_bytes(cfg, x_shape, w_shape,
                                 itemsize) <= VMEM_BUDGET_BYTES:
                cands.append(cfg)
    return _dedup(cands)


def fc_bwd_candidates(x_shape, w_shape, itemsize: int = 4) -> list[dict]:
    B, _ = x_shape
    cands = [dict(FC_BWD_BASELINE)]
    for bb in _divisors(B, 64):
        cfg = {"batch_block": bb}
        if fc_bwd_vmem_bytes(cfg, x_shape, w_shape,
                             itemsize) <= VMEM_BUDGET_BYTES:
            cands.append(cfg)
    return _dedup(cands)


def flash_vmem_bytes(cfg, q_shape, k_shape) -> int:
    """Bytes per grid step of the flash forward: q/k/v tiles, the (bq, bk)
    score tile, and the fp32 (m, l, acc) scratch."""
    D, Dv = q_shape[3], k_shape[3]
    bq = min(cfg["block_q"], q_shape[2])
    bk = min(cfg["block_k"], k_shape[2])
    return 4 * (bq * D + bk * D + bk * Dv + bq * bk + bq * (Dv + 2))


def flash_candidates(q_shape, k_shape) -> list[dict]:
    """(block_q, block_k) candidates: power-of-two tiles up to the sequence
    lengths (the kernel clamps to Tq/Tk and pads non-divisors), pruned by
    VMEM footprint; the 512x512 baseline is always included."""
    Tq, Tk = q_shape[2], k_shape[2]
    sizes_q = sorted({min(s, Tq) for s in (64, 128, 256, 512)})
    sizes_k = sorted({min(s, Tk) for s in (64, 128, 256, 512)})
    cands = [dict(FLASH_BASELINE)]
    for bq in sizes_q:
        for bk in sizes_k:
            cfg = {"block_q": bq, "block_k": bk}
            if flash_vmem_bytes(cfg, q_shape, k_shape) <= VMEM_BUDGET_BYTES:
                cands.append(cfg)
    return _dedup(cands)


def get_flash_config(q_shape, k_shape, dtype, *, interpret: bool) -> dict:
    """Tuned (block_q, block_k) for the flash forward at kernel-layout
    shapes q (B, Hq, Tq, D) / k (B, Hkv, Tk, Dv); baseline when untuned."""
    entry = lookup(key_for("flash_fwd", (q_shape, k_shape), dtype,
                           interpret=interpret))
    if entry is not None:
        return entry["config"]
    return dict(FLASH_BASELINE)


def _dedup(cands: list[dict]) -> list[dict]:
    seen, out = set(), []
    for c in cands:
        key = json.dumps(c, sort_keys=True)
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


# ---------------------------------------------------------------------------
# Heuristic defaults (used when nothing has been tuned yet)
# ---------------------------------------------------------------------------
def default_conv_fwd(x_shape, w_shape, itemsize: int = 4) -> dict:
    """Largest whole-map baseline that fits VMEM, shrinking rows first,
    then batch, then output channels."""
    B, H, _, _ = x_shape
    Kk, _, _, Cout = w_shape
    Ho = H - Kk + 1
    cfg = dict(BASELINE)
    for rb in reversed(_divisors(Ho)):
        cfg["row_block"] = rb
        if conv_fwd_vmem_bytes(cfg, x_shape, w_shape,
                               itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    cfg["row_block"] = 1
    for bb in reversed(_divisors(min(B, 8))):
        cfg["batch_block"] = bb
        if conv_fwd_vmem_bytes(cfg, x_shape, w_shape,
                               itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    for cb in reversed(_divisors(Cout, 128)):
        cfg["cout_block"] = cb
        if conv_fwd_vmem_bytes(cfg, x_shape, w_shape,
                               itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    return cfg


def default_conv_bwd(x_shape, w_shape, itemsize: int = 4) -> dict:
    B, H, _, _ = x_shape
    cfg = {"batch_block": 8, "row_block": None}
    for rb in reversed(_divisors(H)):
        cfg["row_block"] = rb
        if conv_bwd_vmem_bytes(cfg, x_shape, w_shape,
                               itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    cfg["row_block"] = 1
    for bb in reversed(_divisors(min(B, 8))):
        cfg["batch_block"] = bb
        if conv_bwd_vmem_bytes(cfg, x_shape, w_shape,
                               itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    return cfg


def default_fc_fwd(x_shape, w_shape, itemsize: int = 4) -> dict:
    """Largest whole-row baseline that fits VMEM, shrinking the output
    column block first, then the batch block."""
    B, _ = x_shape
    _, Dout = w_shape
    cfg = dict(FC_BASELINE)
    for db in reversed(_divisors(Dout)):
        cfg["dout_block"] = db
        if fc_fwd_vmem_bytes(cfg, x_shape, w_shape,
                             itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    cfg["dout_block"] = 1
    for bb in reversed(_divisors(min(B, 8))):
        cfg["batch_block"] = bb
        if fc_fwd_vmem_bytes(cfg, x_shape, w_shape,
                             itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    return cfg


def default_fc_bwd(x_shape, w_shape, itemsize: int = 4) -> dict:
    B, _ = x_shape
    cfg = dict(FC_BWD_BASELINE)
    for bb in reversed(_divisors(min(B, 8))):
        cfg["batch_block"] = bb
        if fc_bwd_vmem_bytes(cfg, x_shape, w_shape,
                             itemsize) <= VMEM_BUDGET_BYTES:
            return cfg
    return cfg


def get_conv_fwd_config(x_shape, w_shape, dtype, *, interpret: bool,
                        variant: str = "plain") -> dict:
    entry = lookup(key_for("conv_fwd", (x_shape, w_shape), dtype,
                           interpret=interpret, variant=variant))
    if entry is not None:
        return entry["config"]
    return default_conv_fwd(x_shape, w_shape, jnp.dtype(dtype).itemsize)


def get_conv_bwd_config(x_shape, w_shape, dtype, *, interpret: bool,
                        variant: str = "plain") -> dict:
    entry = lookup(key_for("conv_bwd", (x_shape, w_shape), dtype,
                           interpret=interpret, variant=variant))
    if entry is not None:
        return entry["config"]
    return default_conv_bwd(x_shape, w_shape, jnp.dtype(dtype).itemsize)


def get_fc_fwd_config(x_shape, w_shape, dtype, *, interpret: bool,
                      variant: str = "plain") -> dict:
    entry = lookup(key_for("fc_fwd", (x_shape, w_shape), dtype,
                           interpret=interpret, variant=variant))
    if entry is not None:
        return entry["config"]
    return default_fc_fwd(x_shape, w_shape, jnp.dtype(dtype).itemsize)


def get_fc_bwd_config(x_shape, w_shape, dtype, *, interpret: bool,
                      variant: str = "plain") -> dict:
    entry = lookup(key_for("fc_bwd", (x_shape, w_shape), dtype,
                           interpret=interpret, variant=variant))
    if entry is not None:
        return entry["config"]
    return default_fc_bwd(x_shape, w_shape, jnp.dtype(dtype).itemsize)


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------
def _time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


@_traced
def tune_conv_fwd(x, w, bias=None, *, activation: str | None = None,
                  interpret: bool = True, iters: int = 3,
                  max_candidates: int | None = None):
    """Measure all pruned candidates for the forward kernel; cache + return
    ``(best_config, report)``.  The baseline is always measured, so
    ``best_us <= baseline_us`` by construction."""
    variant = "bias_tanh" if activation == "tanh" else "plain"
    key = key_for("conv_fwd", (x.shape, w.shape), x.dtype,
                  interpret=interpret, variant=variant)
    cands = conv_fwd_candidates(x.shape, w.shape, x.dtype.itemsize)
    if max_candidates:
        cands = cands[:max_candidates]
    measured = {}
    for cfg in cands:
        fn = jax.jit(lambda x, w, cfg=cfg: K.conv2d_fwd(
            x, w, bias, activation=activation, interpret=interpret, **cfg))
        measured[json.dumps(cfg, sort_keys=True)] = _time_us(
            fn, x, w, iters=iters)
    best_key = min(measured, key=measured.get)
    best = json.loads(best_key)
    record(key, best, measured[best_key], measured, iters=iters)
    return best, {"key": key, "best_us": measured[best_key],
                  "baseline_us": measured[json.dumps(dict(BASELINE),
                                                     sort_keys=True)],
                  "candidates": measured}


@_traced
def tune_conv_bwd(x, dy, w, y=None, *, interpret: bool = True,
                  iters: int = 3, max_candidates: int | None = None):
    """Measure candidates for the fused backward kernel (dtanh-fused when
    ``y`` is given); cache + return ``(best_config, report)``."""
    variant = "dtanh" if y is not None else "plain"
    key = key_for("conv_bwd", (x.shape, w.shape), x.dtype,
                  interpret=interpret, variant=variant)
    cands = conv_bwd_candidates(x.shape, w.shape, x.dtype.itemsize)
    if max_candidates:
        cands = cands[:max_candidates]
    measured = {}
    for cfg in cands:
        fn = jax.jit(lambda x, dy, w, cfg=cfg: K.conv2d_bwd_fused(
            x, dy, w, y, interpret=interpret, **cfg))
        measured[json.dumps(cfg, sort_keys=True)] = _time_us(
            fn, x, dy, w, iters=iters)
    best_key = min(measured, key=measured.get)
    best = json.loads(best_key)
    record(key, best, measured[best_key], measured, iters=iters)
    return best, {"key": key, "best_us": measured[best_key],
                  "baseline_us": measured[json.dumps(dict(BWD_BASELINE),
                                                     sort_keys=True)],
                  "candidates": measured}


@_traced
def tune_fc_fwd(x, w, bias=None, *, activation: str | None = None,
                interpret: bool = True, iters: int = 3,
                max_candidates: int | None = None):
    """Measure all pruned candidates for the fused FC forward; cache +
    return ``(best_config, report)``.  Same contract as the conv tuners:
    the batch_block=8 whole-row baseline is always measured."""
    variant = "bias_tanh" if activation == "tanh" else "plain"
    key = key_for("fc_fwd", (x.shape, w.shape), x.dtype,
                  interpret=interpret, variant=variant)
    cands = fc_fwd_candidates(x.shape, w.shape, x.dtype.itemsize)
    if max_candidates:
        cands = cands[:max_candidates]
    measured = {}
    for cfg in cands:
        fn = jax.jit(lambda x, w, cfg=cfg: FCK.fc_fwd(
            x, w, bias, activation=activation, interpret=interpret, **cfg))
        measured[json.dumps(cfg, sort_keys=True)] = _time_us(
            fn, x, w, iters=iters)
    best_key = min(measured, key=measured.get)
    best = json.loads(best_key)
    record(key, best, measured[best_key], measured, iters=iters)
    return best, {"key": key, "best_us": measured[best_key],
                  "baseline_us": measured[json.dumps(dict(FC_BASELINE),
                                                     sort_keys=True)],
                  "candidates": measured}


@_traced
def tune_fc_bwd(x, dy, w, y=None, *, interpret: bool = True, iters: int = 3,
                max_candidates: int | None = None):
    """Measure candidates for the fused FC backward (dtanh-fused when ``y``
    is given); cache + return ``(best_config, report)``."""
    variant = "dtanh" if y is not None else "plain"
    key = key_for("fc_bwd", (x.shape, w.shape), x.dtype,
                  interpret=interpret, variant=variant)
    cands = fc_bwd_candidates(x.shape, w.shape, x.dtype.itemsize)
    if max_candidates:
        cands = cands[:max_candidates]
    measured = {}
    for cfg in cands:
        fn = jax.jit(lambda x, dy, w, cfg=cfg: FCK.fc_bwd_fused(
            x, dy, w, y, interpret=interpret, **cfg))
        measured[json.dumps(cfg, sort_keys=True)] = _time_us(
            fn, x, dy, w, iters=iters)
    best_key = min(measured, key=measured.get)
    best = json.loads(best_key)
    record(key, best, measured[best_key], measured, iters=iters)
    return best, {"key": key, "best_us": measured[best_key],
                  "baseline_us": measured[json.dumps(dict(FC_BWD_BASELINE),
                                                     sort_keys=True)],
                  "candidates": measured}


@_traced
def tune_flash_attention(q, k, v, *, causal: bool = True,
                         interpret: bool = True, iters: int = 3,
                         max_candidates: int | None = None):
    """Measure (block_q, block_k) candidates for the Pallas flash forward
    (q, k, v in kernel layout (B, H, T, D)); cache + return
    ``(best_config, report)``.  Same contract as the conv/FC tuners: the
    512x512 baseline is always measured, so ``best_us <= baseline_us``."""
    from repro.kernels import flash_attention as FA

    key = key_for("flash_fwd", (q.shape, k.shape), q.dtype,
                  interpret=interpret)
    cands = flash_candidates(q.shape, k.shape)
    if max_candidates:
        cands = cands[:max_candidates]
    measured = {}
    for cfg in cands:
        fn = jax.jit(lambda q, k, v, cfg=cfg: FA.flash_attention_fwd(
            q, k, v, causal=causal, interpret=interpret, **cfg))
        measured[json.dumps(cfg, sort_keys=True)] = _time_us(
            fn, q, k, v, iters=iters)
    best_key = min(measured, key=measured.get)
    best = json.loads(best_key)
    record(key, best, measured[best_key], measured, iters=iters)
    return best, {"key": key, "best_us": measured[best_key],
                  "baseline_us": measured[json.dumps(dict(FLASH_BASELINE),
                                                     sort_keys=True)],
                  "candidates": measured}


def tune_lm_attention(cfg, batch: int, seq: int, *, iters: int = 1,
                      interpret: bool | None = None):
    """Tune the flash forward at an LM config's training attention shape —
    exactly the cache key ``flash_attention_train`` looks up (q is
    (batch, n_heads, seq, d_head) after the BTHD -> BHTD transpose).  The
    worker mesh runs per-shard batches, so callers pass the per-shard
    batch.  Returns the list of cache keys written."""
    if interpret is None:
        from repro.kernels import ops as kops
        interpret = kops._interpret()
    dtype = jnp.dtype(cfg.param_dtype)
    kk = jax.random.key(0)
    q = jax.random.normal(kk, (batch, cfg.n_heads, seq, cfg.d_head), dtype)
    k = jax.random.normal(kk, (batch, cfg.n_kv_heads, seq, cfg.d_head),
                          dtype)
    v = jax.random.normal(kk, (batch, cfg.n_kv_heads, seq, cfg.d_head),
                          dtype)
    _, rep = tune_flash_attention(q, k, v, causal=True, iters=iters,
                                  interpret=interpret)
    return [rep["key"]]


def tune_cnn_net(cfg, batch: int, *, iters: int = 1,
                 interpret: bool | None = None):
    """Tune every fused conv/FC kernel of a Table-2 CNN at the given batch
    size, populating exactly the cache keys the training path looks up.

    The worker-mesh route (DESIGN.md §4) shards the global batch into
    ``WorkerConfig.logical_shards`` micro-shards, so its kernels run at a
    per-shard batch (e.g. 1) whose autotune keys differ from the full-batch
    keys ``benchmarks/run.py --only kernels`` populates — scaling runs call
    this first so kernel-on cells measure tuned configs, not the heuristic
    fallback.  Returns the list of cache keys written."""
    from repro.models.cnn import _trace_shapes  # local: avoid import cycle

    if interpret is None:
        from repro.kernels import ops as kops
        interpret = kops._interpret()
    keys = []
    h = cfg.cnn_input[0]  # input spatial size of the NEXT layer
    kk = jax.random.key(0)
    shapes = _trace_shapes(cfg)
    for i, (kind, k, h_out, cin, cout) in enumerate(shapes):
        if kind == "conv":
            x = jax.random.normal(kk, (batch, h, h, cin), jnp.float32)
            w = jax.random.normal(kk, (k, k, cin, cout), jnp.float32) * 0.1
            b = jnp.zeros((cout,), jnp.float32)
            dy = jax.random.normal(kk, (batch, h_out, h_out, cout),
                                   jnp.float32)
            y = jnp.tanh(dy)
            _, rep = tune_conv_fwd(x, w, b, activation="tanh", iters=iters,
                                   interpret=interpret)
            keys.append(rep["key"])
            _, rep = tune_conv_bwd(x, dy, w, y, iters=iters,
                                   interpret=interpret)
            keys.append(rep["key"])
            h = h_out
        elif kind == "pool":
            h = h_out
        else:  # fc — tanh epilogue on hidden layers, plain on the head
            x = jax.random.normal(kk, (batch, cin), jnp.float32)
            w = jax.random.normal(kk, (cin, cout), jnp.float32) * 0.1
            b = jnp.zeros((cout,), jnp.float32)
            dy = jax.random.normal(kk, (batch, cout), jnp.float32)
            # positional, matching models/cnn.py::forward's head test —
            # a hidden fc as wide as n_classes must still tune the tanh
            # variants the model actually launches
            last = i == len(shapes) - 1
            act = None if last else "tanh"
            _, rep = tune_fc_fwd(x, w, b, activation=act, iters=iters,
                                 interpret=interpret)
            keys.append(rep["key"])
            _, rep = tune_fc_bwd(x, dy, w, None if last else jnp.tanh(dy),
                                 iters=iters, interpret=interpret)
            keys.append(rep["key"])
            h = 1
    return keys
