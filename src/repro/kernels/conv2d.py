"""Pallas TPU kernel for the paper's hot-spot: convolutional layers.

Hardware adaptation (DESIGN.md §2): the paper vectorises the conv partial-
derivative/weight-gradient loops with 512-bit SIMD + 64-byte-aligned loads.
On TPU the analogue is MXU matmuls over VMEM-resident tiles: each grid step
keeps a batch-block of feature maps in VMEM and reduces the KxK shifted
windows with (bb*Ho*Wo, Cin) x (Cin, Cout) dots — an implicit-im2col
formulation (kernel taps unrolled, contraction on the channel dim feeds the
systolic array).

MNIST-scale maps (<=29x29) fit whole images in VMEM, so the grid tiles the
batch dimension only; the same structure scales to larger maps by adding a
row-block grid dim.  On real TPUs Cin/Cout should be padded to lane
multiples (8/128); ``ops.py`` handles that at the wrapper level.

Forward + both backward kernels (dx, dw) are provided — backprop of the
convolutional layer is 88% of the paper's total time (Table 5), so the
gradient path is the part that matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_fwd_kernel(x_ref, w_ref, o_ref, *, K: int, Ho: int, Wo: int):
    x = x_ref[...]        # (bb, H, W, Cin) in VMEM
    w = w_ref[...]        # (K, K, Cin, Cout) in VMEM
    bb = x.shape[0]
    Cin, Cout = w.shape[2], w.shape[3]
    acc = jnp.zeros((bb * Ho * Wo, Cout), jnp.float32)
    for kh in range(K):           # static unroll: K*K MXU dots
        for kw in range(K):
            patch = x[:, kh:kh + Ho, kw:kw + Wo, :].reshape(bb * Ho * Wo, Cin)
            acc += jnp.dot(patch, w[kh, kw],
                           preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(bb, Ho, Wo, Cout).astype(o_ref.dtype)


def conv2d_fwd(x, w, *, batch_block: int = 8, interpret: bool = True):
    B, H, W, Cin = x.shape
    K, _, _, Cout = w.shape
    Ho, Wo = H - K + 1, W - K + 1
    bb = min(batch_block, B)
    while B % bb:
        bb -= 1
    kern = functools.partial(_conv_fwd_kernel, K=K, Ho=Ho, Wo=Wo)
    return pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, H, W, Cin), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((K, K, Cin, Cout), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, Ho, Wo, Cout), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, Cout), x.dtype),
        interpret=interpret,
    )(x, w)


def _conv_dx_kernel(dy_ref, w_ref, dx_ref, *, K: int, H: int, W: int):
    """dx = full-correlation of dy with w flipped: implemented as the same
    shifted-window MXU reduction over a zero-padded dy block."""
    dy = dy_ref[...]      # (bb, Ho, Wo, Cout)
    w = w_ref[...]        # (K, K, Cin, Cout)
    bb, Ho, Wo, Cout = dy.shape
    Cin = w.shape[2]
    pad = K - 1
    dyp = jnp.pad(dy, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    acc = jnp.zeros((bb * H * W, Cin), jnp.float32)
    for kh in range(K):
        for kw in range(K):
            patch = dyp[:, kh:kh + H, kw:kw + W, :].reshape(bb * H * W, Cout)
            # flipped taps: w[K-1-kh, K-1-kw] transposed (Cout, Cin)
            acc += jnp.dot(patch, w[K - 1 - kh, K - 1 - kw].T,
                           preferred_element_type=jnp.float32)
    dx_ref[...] = acc.reshape(bb, H, W, Cin).astype(dx_ref.dtype)


def conv2d_dx(dy, w, x_shape, *, batch_block: int = 8,
              interpret: bool = True):
    B, H, W, Cin = x_shape
    K = w.shape[0]
    Ho, Wo = dy.shape[1], dy.shape[2]
    Cout = dy.shape[3]
    bb = min(batch_block, B)
    while B % bb:
        bb -= 1
    kern = functools.partial(_conv_dx_kernel, K=K, H=H, W=W)
    return pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, Ho, Wo, Cout), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((K, K, Cin, Cout), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, H, W, Cin), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cin), dy.dtype),
        interpret=interpret,
    )(dy, w)


def _conv_dw_kernel(x_ref, dy_ref, dw_ref, *, K: int):
    """Weight gradients — the paper's SIMD-vectorised loop (Listing 1).
    Each grid step accumulates a batch-block's contribution:
    dw[kh,kw] += patch^T @ dy  (contraction over batch*spatial on the MXU)."""
    x = x_ref[...]        # (bb, H, W, Cin)
    dy = dy_ref[...]      # (bb, Ho, Wo, Cout)
    bb, Ho, Wo, Cout = dy.shape
    Cin = x.shape[3]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    dyf = dy.reshape(bb * Ho * Wo, Cout).astype(jnp.float32)
    for kh in range(K):
        for kw in range(K):
            patch = x[:, kh:kh + Ho, kw:kw + Wo, :].reshape(
                bb * Ho * Wo, Cin).astype(jnp.float32)
            dw_ref[kh, kw] += jnp.dot(patch.T, dyf,
                                      preferred_element_type=jnp.float32
                                      ).astype(dw_ref.dtype)


def conv2d_dw(x, dy, w_shape, *, batch_block: int = 8,
              interpret: bool = True):
    B, H, W, Cin = x.shape
    K, _, _, Cout = w_shape
    Ho, Wo = dy.shape[1], dy.shape[2]
    bb = min(batch_block, B)
    while B % bb:
        bb -= 1
    kern = functools.partial(_conv_dw_kernel, K=K)
    return pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, H, W, Cin), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((bb, Ho, Wo, Cout), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((K, K, Cin, Cout), lambda b: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, K, Cin, Cout), jnp.float32),
        interpret=interpret,
    )(x, dy)
