"""Pallas TPU kernels for the paper's hot-spot: convolutional layers.

Hardware adaptation (DESIGN.md §2): the paper vectorises the conv partial-
derivative/weight-gradient loops with 512-bit SIMD + 64-byte-aligned loads.
On TPU the analogue is MXU matmuls over VMEM-resident tiles: each grid step
keeps a tile of the feature maps in VMEM and reduces the KxK shifted windows
with (bb*rb*Wo, Cin) x (Cin, Cout) dots — an implicit-im2col formulation
(kernel taps unrolled, contraction on the channel dim feeds the systolic
array).

Tiling (DESIGN.md §Kernels): the forward grid is 3-D
(batch-block × output-row-block × Cout-block).  Row blocks read a halo of
``K-1`` extra input rows via unblocked indexing, so feature maps larger than
a single VMEM block (e.g. 64x64) stream through in row slabs instead of
requiring the whole image resident.  Block sizes come from
``kernels/autotune.py`` (or the caller) and must divide the corresponding
dimension.

Fusion: the forward kernel applies a bias + tanh epilogue in-register, and
``conv2d_bwd_fused`` computes dx, dw AND db from ONE shared pass over the
shifted-window patches (with the dtanh factor fused when the forward
activations are supplied) — per-layer backward launches drop from 2 to 1,
which matters because backprop of the conv layers is 88% of the paper's
total time (Table 5).

dw/db accumulate across grid steps in fp32 VMEM scratch, relying on the
TPU's sequential-grid revisiting semantics (tested explicitly for
``batch_block < B`` in tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from contextlib import contextmanager

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# ---------------------------------------------------------------------------
# Launch accounting — lets tests assert how many pallas_call launches a
# train step issues (the fusion win is 3 -> 2 per conv layer).
# ---------------------------------------------------------------------------
_ACTIVE_TRACE = None


def record_launch(name: str) -> None:
    if _ACTIVE_TRACE is not None:
        _ACTIVE_TRACE.append(name)


@contextmanager
def launch_trace():
    """Collect the names of Pallas kernel launches issued inside the block."""
    global _ACTIVE_TRACE
    prev, _ACTIVE_TRACE = _ACTIVE_TRACE, []
    try:
        yield _ACTIVE_TRACE
    finally:
        _ACTIVE_TRACE = prev


def _divisor_block(n: int, want: int | None) -> int:
    """Largest block size <= ``want`` that divides ``n``."""
    d = n if want is None else max(1, min(want, n))
    while n % d:
        d -= 1
    return d


# ---------------------------------------------------------------------------
# Forward: tiled (batch x row x Cout) grid with fused bias+tanh epilogue
# ---------------------------------------------------------------------------
def _conv_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, K: int, rb: int, Wo: int,
                     activation: str | None):
    x = x_ref[...]        # (bb, rb+K-1, W, Cin) halo'd row slab in VMEM
    w = w_ref[...]        # (K, K, Cin, cb)
    bb, Cin = x.shape[0], x.shape[3]
    cb = w.shape[3]
    acc = jnp.zeros((bb * rb * Wo, cb), jnp.float32)
    for kh in range(K):           # static unroll: K*K MXU dots
        for kw in range(K):
            patch = x[:, kh:kh + rb, kw:kw + Wo, :].reshape(bb * rb * Wo, Cin)
            acc += jnp.dot(patch, w[kh, kw],
                           preferred_element_type=jnp.float32)
    acc += b_ref[...].reshape(1, cb).astype(jnp.float32)
    if activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc.reshape(bb, rb, Wo, cb).astype(o_ref.dtype)


def conv2d_fwd(x, w, bias=None, *, activation: str | None = None,
               batch_block: int = 8, row_block: int | None = None,
               cout_block: int | None = None, interpret: bool = True):
    """Valid conv, stride 1, NHWC x HWIO -> NHWC, optional fused bias+tanh.

    Grid is (B/bb, Ho/rb, Cout/cb); the x slab for each row block carries a
    K-1 halo (unblocked indexing), so VMEM holds bb*(rb+K-1)*W*Cin elements
    instead of the whole feature map.
    """
    B, H, W, Cin = x.shape
    K, _, _, Cout = w.shape
    Ho, Wo = H - K + 1, W - K + 1
    bb = _divisor_block(B, batch_block)
    rb = _divisor_block(Ho, row_block)
    cb = _divisor_block(Cout, cout_block)
    b2 = (jnp.zeros((Cout,), x.dtype) if bias is None else bias).reshape(
        1, Cout)
    kern = functools.partial(_conv_fwd_kernel, K=K, rb=rb, Wo=Wo,
                             activation=activation)
    record_launch("conv2d_fwd")
    return pl.pallas_call(
        kern,
        grid=(B // bb, Ho // rb, Cout // cb),
        in_specs=[
            # element offsets (unblocked): row slabs overlap by the K-1 halo
            pl.BlockSpec((bb, rb + K - 1, W, Cin),
                         lambda b, r, c: (b * bb, r * rb, 0, 0),
                         indexing_mode=pl.unblocked),
            pl.BlockSpec((K, K, Cin, cb), lambda b, r, c: (0, 0, 0, c)),
            pl.BlockSpec((1, cb), lambda b, r, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((bb, rb, Wo, cb),
                               lambda b, r, c: (b, r, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, Cout), x.dtype),
        interpret=interpret,
    )(x, w, b2)


# ---------------------------------------------------------------------------
# Fused backward: dx + dw + db from ONE pass over the shifted windows
# ---------------------------------------------------------------------------
def _bwd_body(x, dzp, w, dx_ref, dw_ref, db_ref, dw_acc, db_acc, *,
              K: int, rb: int, W: int, Wo: int):
    """Shared backward pass.  ``x``: (bb, rb+K-1, W, Cin) input slab,
    ``dzp``: (bb, rb+K-1, Wo+2K-2, Cout) zero-padded upstream grad slab
    (already multiplied by dtanh when fusing), ``w``: (K, K, Cin, Cout).

    dx rows [r*rb, r*rb+rb) = correlation of dzp with the flipped taps;
    dw/db accumulate this slab's contribution into fp32 VMEM scratch and
    write out on the last grid step (sequential revisiting semantics).
    """
    bb, Cin = x.shape[0], x.shape[3]
    Cout = dzp.shape[3]
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0)
    last = ((pl.program_id(0) == pl.num_programs(0) - 1) &
            (pl.program_id(1) == pl.num_programs(1) - 1))

    @pl.when(first)
    def _init():
        dw_acc[...] = jnp.zeros_like(dw_acc)
        db_acc[...] = jnp.zeros_like(db_acc)

    # dx: full-correlation with flipped taps, same MXU dot structure
    acc = jnp.zeros((bb * rb * W, Cin), jnp.float32)
    for kh in range(K):
        for kw in range(K):
            patch = dzp[:, kh:kh + rb, kw:kw + W, :].reshape(
                bb * rb * W, Cout)
            acc += jnp.dot(patch, w[K - 1 - kh, K - 1 - kw].T,
                           preferred_element_type=jnp.float32)
    dx_ref[...] = acc.reshape(bb, rb, W, Cin).astype(dx_ref.dtype)

    # dw/db: the valid (un-padded) dz rows of this slab are [K-1, K-1+rb);
    # rows past Ho fall in dzp's zero padding and contribute nothing.
    dzf = dzp[:, K - 1:K - 1 + rb, K - 1:K - 1 + Wo, :].reshape(
        bb * rb * Wo, Cout)
    db_acc[...] += jnp.sum(dzf, axis=0, keepdims=True)
    for kh in range(K):
        for kw in range(K):
            patch = x[:, kh:kh + rb, kw:kw + Wo, :].reshape(
                bb * rb * Wo, Cin).astype(jnp.float32)
            dw_acc[kh, kw] += jnp.dot(patch.T, dzf,
                                      preferred_element_type=jnp.float32)

    @pl.when(last)
    def _flush():
        dw_ref[...] = dw_acc[...].astype(dw_ref.dtype)
        db_ref[...] = db_acc[...].astype(db_ref.dtype)


def _conv_bwd_kernel(xp_ref, dyp_ref, w_ref, dx_ref, dw_ref, db_ref,
                     dw_acc, db_acc, **kw):
    _bwd_body(xp_ref[...], dyp_ref[...].astype(jnp.float32), w_ref[...],
              dx_ref, dw_ref, db_ref, dw_acc, db_acc, **kw)


def _conv_bwd_tanh_kernel(xp_ref, dyp_ref, yp_ref, w_ref, dx_ref, dw_ref,
                          db_ref, dw_acc, db_acc, **kw):
    # dtanh fusion: dz = dy * (1 - y^2); padded entries stay exactly zero.
    y = yp_ref[...].astype(jnp.float32)
    dzp = dyp_ref[...].astype(jnp.float32) * (1.0 - y * y)
    _bwd_body(xp_ref[...], dzp, w_ref[...], dx_ref, dw_ref, db_ref,
              dw_acc, db_acc, **kw)


def conv2d_bwd_fused(x, dy, w, y=None, *, batch_block: int = 8,
                     row_block: int | None = None, interpret: bool = True):
    """One pallas_call -> (dx, dw, db) for the valid conv.

    ``y`` (the forward tanh output) fuses the dtanh factor in-kernel; with
    ``y=None`` the upstream gradient is used as-is (plain conv backward).
    Grid is (B/bb, H/rb) over *input* rows; dy (and y) arrive zero-padded by
    K-1 so halo reads, out-of-range output rows, and the width correlation
    all fall out of the padding — no in-kernel masking needed.
    """
    B, H, W, Cin = x.shape
    K, _, _, Cout = w.shape
    Ho, Wo = dy.shape[1], dy.shape[2]
    bb = _divisor_block(B, batch_block)
    rb = _divisor_block(H, row_block)
    pad = K - 1
    dyp = jnp.pad(dy, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    slab = pl.BlockSpec((bb, rb + pad, Wo + 2 * pad, Cout),
                        lambda b, r: (b * bb, r * rb, 0, 0),
                        indexing_mode=pl.unblocked)
    in_specs = [
        pl.BlockSpec((bb, rb + pad, W, Cin),
                     lambda b, r: (b * bb, r * rb, 0, 0),
                     indexing_mode=pl.unblocked),
        slab,
    ]
    inputs = [xp, dyp]
    if y is not None:
        in_specs.append(slab)
        inputs.append(jnp.pad(y, ((0, 0), (pad, pad), (pad, pad), (0, 0))))
        kern = _conv_bwd_tanh_kernel
    else:
        kern = _conv_bwd_kernel
    in_specs.append(pl.BlockSpec((K, K, Cin, Cout),
                                 lambda b, r: (0, 0, 0, 0)))
    inputs.append(w)
    record_launch("conv2d_bwd_fused")
    dx, dw, db = pl.pallas_call(
        functools.partial(kern, K=K, rb=rb, W=W, Wo=Wo),
        grid=(B // bb, H // rb),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, rb, W, Cin), lambda b, r: (b, r, 0, 0)),
            pl.BlockSpec((K, K, Cin, Cout), lambda b, r: (0, 0, 0, 0)),
            pl.BlockSpec((1, Cout), lambda b, r: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, W, Cin), x.dtype),
            jax.ShapeDtypeStruct((K, K, Cin, Cout), jnp.float32),
            jax.ShapeDtypeStruct((1, Cout), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((K, K, Cin, Cout), jnp.float32),
            pltpu.VMEM((1, Cout), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    return dx, dw, db.reshape(Cout)


# ---------------------------------------------------------------------------
# Split backward kernels — kept as the un-fused baseline (benchmarks compare
# against them) and for callers that only need one of the two gradients.
# ---------------------------------------------------------------------------
def _conv_dx_kernel(dy_ref, w_ref, dx_ref, *, K: int, H: int, W: int):
    """dx = full-correlation of dy with w flipped: implemented as the same
    shifted-window MXU reduction over a zero-padded dy block."""
    dy = dy_ref[...]      # (bb, Ho, Wo, Cout)
    w = w_ref[...]        # (K, K, Cin, Cout)
    bb, Ho, Wo, Cout = dy.shape
    Cin = w.shape[2]
    pad = K - 1
    dyp = jnp.pad(dy, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    acc = jnp.zeros((bb * H * W, Cin), jnp.float32)
    for kh in range(K):
        for kw in range(K):
            patch = dyp[:, kh:kh + H, kw:kw + W, :].reshape(bb * H * W, Cout)
            # flipped taps: w[K-1-kh, K-1-kw] transposed (Cout, Cin)
            acc += jnp.dot(patch, w[K - 1 - kh, K - 1 - kw].T,
                           preferred_element_type=jnp.float32)
    dx_ref[...] = acc.reshape(bb, H, W, Cin).astype(dx_ref.dtype)


def conv2d_dx(dy, w, x_shape, *, batch_block: int = 8,
              interpret: bool = True):
    B, H, W, Cin = x_shape
    K = w.shape[0]
    Ho, Wo = dy.shape[1], dy.shape[2]
    Cout = dy.shape[3]
    bb = _divisor_block(B, batch_block)
    kern = functools.partial(_conv_dx_kernel, K=K, H=H, W=W)
    record_launch("conv2d_dx")
    return pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, Ho, Wo, Cout), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((K, K, Cin, Cout), lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, H, W, Cin), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cin), dy.dtype),
        interpret=interpret,
    )(dy, w)


def _conv_dw_kernel(x_ref, dy_ref, dw_ref, acc_ref, *, K: int):
    """Weight gradients — the paper's SIMD-vectorised loop (Listing 1).
    Each grid step accumulates a batch-block's contribution into fp32 VMEM
    scratch: dw[kh,kw] += patch^T @ dy (contraction over batch*spatial on
    the MXU); the scratch flushes to the output on the last step."""
    x = x_ref[...]        # (bb, H, W, Cin)
    dy = dy_ref[...]      # (bb, Ho, Wo, Cout)
    bb, Ho, Wo, Cout = dy.shape
    Cin = x.shape[3]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dyf = dy.reshape(bb * Ho * Wo, Cout).astype(jnp.float32)
    for kh in range(K):
        for kw in range(K):
            patch = x[:, kh:kh + Ho, kw:kw + Wo, :].reshape(
                bb * Ho * Wo, Cin).astype(jnp.float32)
            acc_ref[kh, kw] += jnp.dot(patch.T, dyf,
                                       preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def conv2d_dw(x, dy, w_shape, *, batch_block: int = 8,
              interpret: bool = True):
    B, H, W, Cin = x.shape
    K, _, _, Cout = w_shape
    Ho, Wo = dy.shape[1], dy.shape[2]
    bb = _divisor_block(B, batch_block)
    kern = functools.partial(_conv_dw_kernel, K=K)
    record_launch("conv2d_dw")
    return pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, H, W, Cin), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((bb, Ho, Wo, Cout), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((K, K, Cin, Cout), lambda b: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, K, Cin, Cout), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, K, Cin, Cout), jnp.float32)],
        interpret=interpret,
    )(x, dy)
