"""Pallas TPU flash-attention forward kernel.

This is the §Perf hillclimb change for the memory-dominant training/prefill
cells: the pure-jnp blockwise attention (models/layers.py) materialises the
per-block score/prob matrices through HBM (XLA cannot fuse across the two
dots), whereas this kernel keeps them in VMEM.

Structure (the canonical TPU pallas flash pattern):
  grid = (B, Hkv*G, n_q_blocks, n_kv_blocks)   -- sequential on TPU
  scratch (VMEM, persists across the innermost kv iterations):
      m (bq,), l (bq,), acc (bq, D)
  @pl.when(kv_idx == 0)         -> init scratch
  each step: s = q @ k^T, online-softmax update of (m, l, acc)
  @pl.when(kv_idx == nk - 1)    -> out = acc / l

Block sizes: bq x D and bk x D tiles; with bq = bk = 512 and D = 128 the
working set is ~1.3MB in f32 — comfortably inside a v5e core's VMEM, and
the (bq, bk) score tile feeds the MXU at 128-aligned shapes.

Validated in interpret mode against models.layers.flash_attention /
the naive oracle (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, m_sc, l_sc,
               acc_sc, *, scale: float, causal: bool, block_q: int,
               block_k: int, n_kv: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_off = off_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)      # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # absolute query position: queries live at cache positions
        # [q_off, q_off + Tq) — the causal frontier of a continued sequence
        # sits at q_off + row, NOT at row (the pre-fix bug: a batched
        # prefill starting mid-cache masked every cached key as "future")
        q_pos = q_off + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc_sc[...] = (acc_sc[...] * corr[:, None]
                       + jax.lax.dot_general(
                           p, v, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32))
        m_sc[...] = m_new
        l_sc[...] = l_new

    if causal:
        # skip fully-masked kv blocks (block start beyond q block end,
        # measured at the ABSOLUTE query position q_off + row)
        @pl.when(ki * block_k <= q_off + qi * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == n_kv - 1)
    def _done():
        l = l_sc[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)
        # log-sum-exp of the (scaled) scores per query row — the training
        # backward's softmax denominator (models/layers.py::_flash_bwd
        # recomputes p = exp(s - lse) per block from it, so the kernel
        # forward needs NO jnp-forward recompute in its VJP)
        lse_ref[0, 0] = m_sc[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal: bool = True, q_offset=0,
                        softmax_scale=None, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True,
                        return_lse: bool = False):
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D/Dv) — GQA by head grouping.
    Returns (B, Hq, Tq, Dv); with ``return_lse`` also the per-row
    log-sum-exp (B, Hkv, G, Tq) f32 in the models/layers convention (the
    flash backward's residual).

    ``q_offset`` (python int or traced int32 scalar) is the absolute cache
    position of query row 0: the causal mask admits ``k_pos <= q_offset +
    row``, matching ``models/layers.py::flash_attention``.  It rides into
    the kernel as a (1, 1) SMEM scalar, so a traced offset does not change
    compiled shapes (one program serves every cache position)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1, 1)

    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Tq + pad_q) // block_q
    nk = (Tk + pad_k) // block_k

    # expand q to (B, Hkv, G*Tq... ) keep heads explicit: fold G into Q rows
    qf = q.reshape(B, Hkv, G, Tq + pad_q, D)

    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv=nk, seq_len=Tk)

    def one_group(qg):  # qg: (B, Hkv, Tq+pad, D)
        return pl.pallas_call(
            kern,
            grid=(B, Hkv, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1, block_q, D),
                             lambda b, h, i, j: (b, h, i, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, i, j: (b, h, j, 0)),
                pl.BlockSpec((1, 1, block_k, Dv),
                             lambda b, h, i, j: (b, h, j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, block_q, Dv),
                             lambda b, h, i, j: (b, h, i, 0)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, i, j: (b, h, i)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((B, Hkv, Tq + pad_q, Dv), q.dtype),
                jax.ShapeDtypeStruct((B, Hkv, Tq + pad_q), jnp.float32),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, Dv), jnp.float32),
            ],
            interpret=interpret,
        )(off, qg, k, v)

    outs = [one_group(qf[:, :, g]) for g in range(G)]
    out = jnp.stack([o for o, _ in outs], axis=2)
    out = out.reshape(B, Hq, Tq + pad_q, Dv)[:, :, :Tq]
    if not return_lse:
        return out
    lse = jnp.stack([l for _, l in outs], axis=2)[:, :, :, :Tq]
    return out, lse


# ---------------------------------------------------------------------------
# Training-grade flash attention: Pallas forward + a real backward
# ---------------------------------------------------------------------------
# The forward launches the kernel above with autotuned (block_q, block_k)
# and also emits the per-row log-sum-exp; the custom VJP saves (q, k, v,
# out, lse) — exactly the jnp flash path's residual set — and the backward
# runs the blockwise flash backward from models/layers.py directly, with
# NO forward recompute.  One shared backward implementation keeps the two
# paths' gradients bit-comparable while the kernel carries the forward.

_BWD_BLOCK_K = 1024  # the jnp backward's kv block (layers.py default)


def _ft_impl(q, k, v, causal, scale, block_q, block_k, interpret):
    qT, kT, vT = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    out, lse = flash_attention_fwd(qT, kT, vT, causal=causal, q_offset=0,
                                   softmax_scale=scale, block_q=block_q,
                                   block_k=block_k, interpret=interpret,
                                   return_lse=True)
    return out.transpose(0, 2, 1, 3), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_train(q, k, v, causal, scale, block_q, block_k, interpret):
    out, _ = _ft_impl(q, k, v, causal, scale, block_q, block_k, interpret)
    return out


def _ft_fwd(q, k, v, causal, scale, block_q, block_k, interpret):
    out, lse = _ft_impl(q, k, v, causal, scale, block_q, block_k, interpret)
    return out, (q, k, v, out, lse)


def _ft_bwd(causal, scale, block_q, block_k, interpret, res, dout):
    from repro.models import layers as L  # deferred: no import cycle
    q, k, v, out, lse = res
    off = jnp.zeros((), jnp.int32)
    dq, dk, dv, _ = L._flash_bwd(causal, _BWD_BLOCK_K, scale,
                                 (q, k, v, out, lse, off), dout)
    return dq, dk, dv


_flash_train.defvjp(_ft_fwd, _ft_bwd)


def flash_attention_train(q, k, v, *, causal: bool = True,
                          softmax_scale=None):
    """Differentiable Pallas flash attention for the LM *training* forward
    (``ArchConfig.use_kernel``): q, k, v in the models/layers (B, T, H, D)
    convention, GQA by head grouping.  Block sizes come from the autotuner
    (``kernels/autotune.py::get_flash_config``, tuned by ``benchmarks/run.py
    --only kernels``), falling back to the 512x512 baseline."""
    from repro.kernels import autotune as AT
    from repro.kernels.ops import _interpret
    B, Tq, Hq, D = q.shape
    scale = (softmax_scale if softmax_scale is not None
             else 1.0 / math.sqrt(D))
    interp = _interpret()
    q_shape = (B, Hq, Tq, D)
    k_shape = (B, k.shape[2], k.shape[1], k.shape[3])
    cfg = AT.get_flash_config(q_shape, k_shape, q.dtype, interpret=interp)
    return _flash_train(q, k, v, causal, scale, cfg["block_q"],
                        cfg["block_k"], interp)
