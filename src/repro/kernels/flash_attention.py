"""Pallas TPU flash-attention forward kernel.

This is the §Perf hillclimb change for the memory-dominant training/prefill
cells: the pure-jnp blockwise attention (models/layers.py) materialises the
per-block score/prob matrices through HBM (XLA cannot fuse across the two
dots), whereas this kernel keeps them in VMEM.

Structure (the canonical TPU pallas flash pattern):
  grid = (B, Hkv*G, n_q_blocks, n_kv_blocks)   -- sequential on TPU
  scratch (VMEM, persists across the innermost kv iterations):
      m (bq,), l (bq,), acc (bq, D)
  @pl.when(kv_idx == 0)         -> init scratch
  each step: s = q @ k^T, online-softmax update of (m, l, acc)
  @pl.when(kv_idx == nk - 1)    -> out = acc / l

Block sizes: bq x D and bk x D tiles; with bq = bk = 512 and D = 128 the
working set is ~1.3MB in f32 — comfortably inside a v5e core's VMEM, and
the (bq, bk) score tile feeds the MXU at 128-aligned shapes.

Validated in interpret mode against models.layers.flash_attention /
the naive oracle (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               n_kv: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_off = off_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)      # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # absolute query position: queries live at cache positions
        # [q_off, q_off + Tq) — the causal frontier of a continued sequence
        # sits at q_off + row, NOT at row (the pre-fix bug: a batched
        # prefill starting mid-cache masked every cached key as "future")
        q_pos = q_off + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_sc[...], l_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1)
        acc_sc[...] = (acc_sc[...] * corr[:, None]
                       + jax.lax.dot_general(
                           p, v, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32))
        m_sc[...] = m_new
        l_sc[...] = l_new

    if causal:
        # skip fully-masked kv blocks (block start beyond q block end,
        # measured at the ABSOLUTE query position q_off + row)
        @pl.when(ki * block_k <= q_off + qi * block_q + block_q - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(ki == n_kv - 1)
    def _done():
        l = l_sc[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, q_offset=0,
                        softmax_scale=None, block_q: int = 512,
                        block_k: int = 512, interpret: bool = True):
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D/Dv) — GQA by head grouping.
    Returns (B, Hq, Tq, Dv).

    ``q_offset`` (python int or traced int32 scalar) is the absolute cache
    position of query row 0: the causal mask admits ``k_pos <= q_offset +
    row``, matching ``models/layers.py::flash_attention``.  It rides into
    the kernel as a (1, 1) SMEM scalar, so a traced offset does not change
    compiled shapes (one program serves every cache position)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk, Dv = k.shape[1], k.shape[2], v.shape[3]
    G = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else 1 / math.sqrt(D)
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    off = jnp.asarray(q_offset, jnp.int32).reshape(1, 1)

    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Tq + pad_q) // block_q
    nk = (Tk + pad_k) // block_k

    # expand q to (B, Hkv, G*Tq... ) keep heads explicit: fold G into Q rows
    qf = q.reshape(B, Hkv, G, Tq + pad_q, D)

    kern = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv=nk, seq_len=Tk)

    def one_group(qg):  # qg: (B, Hkv, Tq+pad, D)
        return pl.pallas_call(
            kern,
            grid=(B, Hkv, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1, block_q, D),
                             lambda b, h, i, j: (b, h, i, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, i, j: (b, h, j, 0)),
                pl.BlockSpec((1, 1, block_k, Dv),
                             lambda b, h, i, j: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                                   lambda b, h, i, j: (b, h, i, 0)),
            out_shape=jax.ShapeDtypeStruct((B, Hkv, Tq + pad_q, Dv), q.dtype),
            scratch_shapes=[
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q,), jnp.float32),
                pltpu.VMEM((block_q, Dv), jnp.float32),
            ],
            interpret=interpret,
        )(off, qg, k, v)

    outs = [one_group(qf[:, :, g]) for g in range(G)]
    out = jnp.stack(outs, axis=2).reshape(B, Hq, Tq + pad_q, Dv)
    return out[:, :, :Tq]
