"""jit'd public wrappers for the Pallas kernels, with custom VJPs so the
training path (the paper's hot-spot: conv backprop, Table 5) also runs
through Pallas — and through the autotuner's block configs (DESIGN.md
§Kernels).

Per conv layer per train step this issues exactly TWO pallas_call launches:
one fused forward (conv + bias + tanh) and one fused backward (dx + dw + db
from a single pass, dtanh folded in), down from three with the split
fwd/dx/dw kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only — the
kernels execute their bodies in Python for correctness validation; on a
real TPU set REPRO_PALLAS_INTERPRET=0 or rely on backend detection).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels import autotune as AT
from repro.kernels import conv2d as K
from repro.kernels import fc as FC
from repro.kernels import pool as P


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


def _fwd_cfg(x, w, variant="plain"):
    return AT.get_conv_fwd_config(x.shape, w.shape, x.dtype,
                                  interpret=_interpret(), variant=variant)


def _bwd_cfg(x, w, variant="plain"):
    return AT.get_conv_bwd_config(x.shape, w.shape, x.dtype,
                                  interpret=_interpret(), variant=variant)


# ---------------------------------------------------------------------------
# Plain valid conv (no epilogue) — kept for callers that fuse nothing
# ---------------------------------------------------------------------------
@jax.custom_vjp
def conv2d_valid(x, w):
    """Valid conv, stride 1, NHWC x HWIO -> NHWC.  Pallas forward+backward,
    autotuned block sizes, fused single-launch backward."""
    return K.conv2d_fwd(x, w, interpret=_interpret(), **_fwd_cfg(x, w))


def _cv_fwd(x, w):
    return conv2d_valid(x, w), (x, w)


def _cv_bwd(res, dy):
    x, w = res
    dx, dw, _db = K.conv2d_bwd_fused(x, dy, w, interpret=_interpret(),
                                     **_bwd_cfg(x, w))
    return dx.astype(x.dtype), dw.astype(w.dtype)


conv2d_valid.defvjp(_cv_fwd, _cv_bwd)


# ---------------------------------------------------------------------------
# Fused conv + bias + tanh — the CNN layer op (models/cnn.py hot path)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def conv2d_bias_tanh(x, w, b):
    """tanh(conv2d_valid(x, w) + b) in one forward launch; the backward is
    one launch too (dtanh + dx + dw + db fused)."""
    return K.conv2d_fwd(x, w, b, activation="tanh", interpret=_interpret(),
                        **_fwd_cfg(x, w, "bias_tanh"))


def _cbt_fwd(x, w, b):
    y = conv2d_bias_tanh(x, w, b)
    return y, (x, w, b, y)


def _cbt_bwd(res, dy):
    x, w, b, y = res
    dx, dw, db = K.conv2d_bwd_fused(x, dy, w, y, interpret=_interpret(),
                                    **_bwd_cfg(x, w, "dtanh"))
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype)


conv2d_bias_tanh.defvjp(_cbt_fwd, _cbt_bwd)


# ---------------------------------------------------------------------------
# Max pooling (stride == window, VALID) — Pallas both ways
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(1,))
def maxpool2d(x, k: int):
    """Max pool with window k, stride k, VALID; Pallas forward + backward."""
    return P.maxpool2d_fwd(x, k, interpret=_interpret())


def _mp_fwd(x, k):
    y = maxpool2d(x, k)
    return y, (x, y)


def _mp_bwd(k, res, dy):
    x, y = res
    return (P.maxpool2d_bwd(x, y, dy, k, interpret=_interpret()),)


maxpool2d.defvjp(_mp_fwd, _mp_bwd)


# ---------------------------------------------------------------------------
# Fused FC layers (matmul + bias [+ tanh]) — the CNN tail (kernels/fc.py)
# ---------------------------------------------------------------------------
def _fcf_cfg(x, w, variant="plain"):
    return AT.get_fc_fwd_config(x.shape, w.shape, x.dtype,
                                interpret=_interpret(), variant=variant)


def _fcb_cfg(x, w, variant="plain"):
    return AT.get_fc_bwd_config(x.shape, w.shape, x.dtype,
                                interpret=_interpret(), variant=variant)


@jax.custom_vjp
def fc_bias_tanh(x, w, b):
    """tanh(x @ w + b) in one forward launch; one fused backward launch
    (dtanh + dx + dw + db)."""
    return FC.fc_fwd(x, w, b, activation="tanh", interpret=_interpret(),
                     **_fcf_cfg(x, w, "bias_tanh"))


def _fbt_fwd(x, w, b):
    y = fc_bias_tanh(x, w, b)
    return y, (x, w, b, y)


def _fbt_bwd(res, dy):
    x, w, b, y = res
    dx, dw, db = FC.fc_bwd_fused(x, dy, w, y, interpret=_interpret(),
                                 **_fcb_cfg(x, w, "dtanh"))
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype)


fc_bias_tanh.defvjp(_fbt_fwd, _fbt_bwd)


@jax.custom_vjp
def fc_bias(x, w, b):
    """x @ w + b (linear output layer) — fused forward, fused backward."""
    return FC.fc_fwd(x, w, b, activation=None, interpret=_interpret(),
                     **_fcf_cfg(x, w, "plain"))


def _fb_fwd(x, w, b):
    return fc_bias(x, w, b), (x, w, b)


def _fb_bwd(res, dy):
    x, w, b = res
    dx, dw, db = FC.fc_bwd_fused(x, dy, w, interpret=_interpret(),
                                 **_fcb_cfg(x, w, "plain"))
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype)


fc_bias.defvjp(_fb_fwd, _fb_bwd)


# ---------------------------------------------------------------------------
# Saved-activation backward entry points (models/cnn.py shard tape)
# ---------------------------------------------------------------------------
# The worker-mesh bucket tape checkpoints every layer's output during its
# forward pass, so its backward can call the fused backward kernels
# DIRECTLY with the saved activations instead of re-linearising the layer
# (``jax.vjp`` re-runs the forward to rebuild residuals).  These are the
# exact same kernel launches the custom-VJP wrappers above issue — same
# configs, same casts — so the tape's gradients stay bit-comparable.


def conv2d_bias_tanh_bwd(x, w, b, y, dy):
    """Fused (dx, dw, db) for ``conv2d_bias_tanh`` from the saved output
    ``y`` — one launch, no forward recompute."""
    dx, dw, db = K.conv2d_bwd_fused(x, dy, w, y, interpret=_interpret(),
                                    **_bwd_cfg(x, w, "dtanh"))
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype)


def fc_bias_tanh_bwd(x, w, b, y, dy):
    """Fused (dx, dw, db) for ``fc_bias_tanh`` from the saved output."""
    dx, dw, db = FC.fc_bwd_fused(x, dy, w, y, interpret=_interpret(),
                                 **_fcb_cfg(x, w, "dtanh"))
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype)


def fc_bias_bwd(x, w, b, dy):
    """Fused (dx, dw, db) for the linear ``fc_bias`` output layer."""
    dx, dw, db = FC.fc_bwd_fused(x, dy, w, interpret=_interpret(),
                                 **_fcb_cfg(x, w, "plain"))
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype)


def maxpool2d_vjp_saved(x, y, dy, k: int):
    """``maxpool2d`` backward from the saved (x, y) pair — the same single
    Pallas launch the custom VJP issues."""
    return P.maxpool2d_bwd(x, y, dy, k, interpret=_interpret())


# ---------------------------------------------------------------------------
# Fused softmax-cross-entropy: per-sample loss, dlogits saved as residual
# so the backward costs ZERO extra launches
# ---------------------------------------------------------------------------
@jax.custom_vjp
def softmax_xent(logits, labels):
    """Per-sample CE loss (B,) for logits (B, C) and int labels (B,)."""
    loss, _ = FC.softmax_xent_fwd(logits, labels, interpret=_interpret())
    return loss


def _sx_fwd(logits, labels):
    loss, dl = FC.softmax_xent_fwd(logits, labels, interpret=_interpret())
    return loss, (dl, labels.shape)


def _sx_bwd(res, g):
    dl, lab_shape = res
    # labels are integer-valued: their cotangent is the symbolic float0 zero
    return (dl * g[:, None].astype(dl.dtype),
            np.zeros(lab_shape, dtype=jax.dtypes.float0))


softmax_xent.defvjp(_sx_fwd, _sx_bwd)
