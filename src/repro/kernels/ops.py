"""jit'd public wrappers for the Pallas kernels, with custom VJP so the
training path (the paper's hot-spot: conv backprop, Table 5) also runs
through Pallas.

``interpret`` defaults to True off-TPU (this container is CPU-only — the
kernels execute their bodies in Python for correctness validation; on a
real TPU set REPRO_PALLAS_INTERPRET=0 or rely on backend detection).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import conv2d as K


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false")
    return jax.default_backend() != "tpu"


@jax.custom_vjp
def conv2d_valid(x, w):
    """Valid conv, stride 1, NHWC x HWIO -> NHWC.  Pallas forward+backward."""
    return K.conv2d_fwd(x, w, interpret=_interpret())


def _fwd(x, w):
    return conv2d_valid(x, w), (x, w)


def _bwd(res, dy):
    x, w = res
    interp = _interpret()
    dx = K.conv2d_dx(dy, w, x.shape, interpret=interp).astype(x.dtype)
    dw = K.conv2d_dw(x, dy, w.shape, interpret=interp).astype(w.dtype)
    return dx, dw


conv2d_valid.defvjp(_fwd, _bwd)
