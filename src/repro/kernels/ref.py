"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_valid_ref(x, w):
    """x: (B, H, W, Cin) NHWC; w: (K, K, Cin, Cout) HWIO; VALID, stride 1."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv2d_dw_ref(x, dy):
    """Weight gradient of conv2d_valid.  x: (B,H,W,Cin), dy: (B,Ho,Wo,Cout)
    -> (K,K,Cin,Cout)."""
    B, H, W, Cin = x.shape
    _, Ho, Wo, Cout = dy.shape
    K = H - Ho + 1
    out = jnp.zeros((K, K, Cin, Cout), jnp.float32)
    for kh in range(K):
        for kw in range(K):
            patch = x[:, kh:kh + Ho, kw:kw + Wo, :].astype(jnp.float32)
            out = out.at[kh, kw].set(
                jnp.einsum("bhwc,bhwo->co", patch, dy.astype(jnp.float32)))
    return out
