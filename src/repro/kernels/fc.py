"""Pallas kernels for the FC + softmax tail of the paper's CNNs.

The conv trunk got the tiling/fusion/autotune treatment in the first
kernel pass (Table 5: conv backprop is 88% of step time); the FC layers
and the softmax output are the remaining hot fraction, and Krizhevsky's
"one weird trick" (arXiv:1404.5997) argues they deserve their own
treatment.  Three kernels:

``fc_fwd``           y = act(x @ w + b) in one launch — the matmul runs on
                     the MXU with an fp32 accumulator, the bias + tanh
                     epilogue stays in-register.

``fc_bwd_fused``     dx, dw AND db from one launch (the dtanh factor fused
                     when the forward activations are supplied): dz shares
                     one VMEM residency for all three products; dw/db
                     accumulate across batch-grid steps in fp32 scratch,
                     the same sequential-grid pattern as the conv backward.

``softmax_xent_fwd`` per-sample CE loss and dlogits (softmax - onehot)
                     from one pass over the logits: the backward of the
                     loss costs zero extra launches (dlogits is saved as
                     the residual).

Grids are (batch-block × dout-block) forward and (batch-block,) backward;
block sizes come from ``kernels/autotune.py`` like the conv kernels'.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.conv2d import _divisor_block, record_launch


# ---------------------------------------------------------------------------
# Forward: fused matmul + bias + tanh epilogue
# ---------------------------------------------------------------------------
def _fc_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str | None):
    x = x_ref[...]                       # (bb, Din)
    w = w_ref[...]                       # (Din, db)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc += b_ref[...].astype(jnp.float32)          # (1, db)
    if activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def fc_fwd(x, w, bias=None, *, activation: str | None = None,
           batch_block: int = 8, dout_block: int | None = None,
           interpret: bool = True):
    """act(x @ w + b); x: (B, Din), w: (Din, Dout), b: (Dout,) -> (B, Dout).

    Grid is (B/bb, Dout/db); each step holds an x row block, a w column
    block, and the fp32 accumulator for its output tile in VMEM.
    """
    B, Din = x.shape
    _, Dout = w.shape
    bb = _divisor_block(B, batch_block)
    db = _divisor_block(Dout, dout_block)
    b2 = (jnp.zeros((Dout,), x.dtype) if bias is None else bias).reshape(
        1, Dout)
    record_launch("fc_fwd")
    return pl.pallas_call(
        functools.partial(_fc_fwd_kernel, activation=activation),
        grid=(B // bb, Dout // db),
        in_specs=[
            pl.BlockSpec((bb, Din), lambda i, j: (i, 0)),
            pl.BlockSpec((Din, db), lambda i, j: (0, j)),
            pl.BlockSpec((1, db), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, db), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, Dout), x.dtype),
        interpret=interpret,
    )(x, w, b2)


# ---------------------------------------------------------------------------
# Fused backward: dx + dw + db (+ dtanh) from ONE launch
# ---------------------------------------------------------------------------
def _fc_bwd_body(x, dz, w, dx_ref, dw_ref, db_ref, dw_acc, db_acc):
    """``x``: (bb, Din), ``dz``: (bb, Dout) fp32 (dtanh already applied
    when fusing), ``w``: (Din, Dout)."""
    first = pl.program_id(0) == 0
    last = pl.program_id(0) == pl.num_programs(0) - 1

    @pl.when(first)
    def _init():
        dw_acc[...] = jnp.zeros_like(dw_acc)
        db_acc[...] = jnp.zeros_like(db_acc)

    dx_ref[...] = jnp.dot(dz, w.T.astype(jnp.float32),
                          preferred_element_type=jnp.float32
                          ).astype(dx_ref.dtype)
    dw_acc[...] += jnp.dot(x.T.astype(jnp.float32), dz,
                           preferred_element_type=jnp.float32)
    db_acc[...] += jnp.sum(dz, axis=0, keepdims=True)

    @pl.when(last)
    def _flush():
        dw_ref[...] = dw_acc[...].astype(dw_ref.dtype)
        db_ref[...] = db_acc[...].astype(db_ref.dtype)


def _fc_bwd_kernel(x_ref, dy_ref, w_ref, dx_ref, dw_ref, db_ref,
                   dw_acc, db_acc):
    _fc_bwd_body(x_ref[...], dy_ref[...].astype(jnp.float32), w_ref[...],
                 dx_ref, dw_ref, db_ref, dw_acc, db_acc)


def _fc_bwd_tanh_kernel(x_ref, dy_ref, y_ref, w_ref, dx_ref, dw_ref, db_ref,
                        dw_acc, db_acc):
    y = y_ref[...].astype(jnp.float32)
    dz = dy_ref[...].astype(jnp.float32) * (1.0 - y * y)
    _fc_bwd_body(x_ref[...], dz, w_ref[...], dx_ref, dw_ref, db_ref,
                 dw_acc, db_acc)


def fc_bwd_fused(x, dy, w, y=None, *, batch_block: int = 8,
                 interpret: bool = True):
    """One pallas_call -> (dx, dw, db) for the fused FC layer.

    ``y`` (the forward tanh output) fuses the dtanh factor in-kernel; with
    ``y=None`` the upstream gradient is used as-is (linear output layer).
    Grid is (B/bb,); dw/db accumulate across batch blocks in fp32 scratch.
    """
    B, Din = x.shape
    _, Dout = w.shape
    bb = _divisor_block(B, batch_block)
    in_specs = [
        pl.BlockSpec((bb, Din), lambda b: (b, 0)),
        pl.BlockSpec((bb, Dout), lambda b: (b, 0)),
    ]
    inputs = [x, dy]
    if y is not None:
        in_specs.append(pl.BlockSpec((bb, Dout), lambda b: (b, 0)))
        inputs.append(y)
        kern = _fc_bwd_tanh_kernel
    else:
        kern = _fc_bwd_kernel
    in_specs.append(pl.BlockSpec((Din, Dout), lambda b: (0, 0)))
    inputs.append(w)
    record_launch("fc_bwd_fused")
    dx, dw, db = pl.pallas_call(
        kern,
        grid=(B // bb,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, Din), lambda b: (b, 0)),
            pl.BlockSpec((Din, Dout), lambda b: (0, 0)),
            pl.BlockSpec((1, Dout), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Din), x.dtype),
            jax.ShapeDtypeStruct((Din, Dout), jnp.float32),
            jax.ShapeDtypeStruct((1, Dout), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Din, Dout), jnp.float32),
            pltpu.VMEM((1, Dout), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs)
    return dx, dw, db.reshape(Dout)


# ---------------------------------------------------------------------------
# Fused softmax + cross-entropy: (loss, dlogits) in one pass
# ---------------------------------------------------------------------------
def _softmax_xent_kernel(l_ref, lab_ref, loss_ref, dl_ref):
    l = l_ref[...].astype(jnp.float32)             # (bb, C)
    lab = lab_ref[...]                             # (bb, 1) int32
    m = jnp.max(l, axis=1, keepdims=True)
    e = jnp.exp(l - m)
    s = jnp.sum(e, axis=1, keepdims=True)
    lse = jnp.log(s) + m
    classes = jax.lax.broadcasted_iota(jnp.int32, l.shape, 1)
    onehot = (classes == lab).astype(jnp.float32)
    ll = jnp.sum(l * onehot, axis=1, keepdims=True)
    loss_ref[...] = (lse - ll).astype(loss_ref.dtype)
    dl_ref[...] = (e / s - onehot).astype(dl_ref.dtype)


def softmax_xent_fwd(logits, labels, *, batch_block: int = 8,
                     interpret: bool = True):
    """Per-sample CE loss and its logits gradient from one launch.

    logits: (B, C), labels: (B,) int -> (loss (B,), dlogits (B, C) where
    dlogits = softmax(logits) - onehot(labels), i.e. d loss_i / d logits_i).
    """
    B, C = logits.shape
    bb = _divisor_block(B, batch_block)
    lab2 = labels.reshape(B, 1).astype(jnp.int32)
    record_launch("softmax_xent")
    loss, dl = pl.pallas_call(
        _softmax_xent_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, C), lambda b: (b, 0)),
            pl.BlockSpec((bb, 1), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1), lambda b: (b, 0)),
            pl.BlockSpec((bb, C), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, C), logits.dtype),
        ],
        interpret=interpret,
    )(logits, lab2)
    return loss.reshape(B), dl
