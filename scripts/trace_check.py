#!/usr/bin/env python
"""Validate an obs trace.json (DESIGN.md §11) — the CI artifact check.

Three layers, each optional flags deeper than the last:

1. **Format** (always): the file is Chrome-trace JSON Perfetto can load —
   a ``traceEvents`` list whose entries carry ph/ts/pid/tid, with process
   and thread name metadata for every referenced track.
2. **Structure** (``--steps/--superstep/--workers``): the driver emitted
   ``steps / superstep`` superstep spans, and every (bucket, worker) pair
   carries exactly ``steps`` ``exchange/<bucket>`` spans — one per
   optimizer step, for every bucket the layerwise schedule exchanges.
3. **Cross-check** (``--bench BENCH_overlap.json``): the per-step summed
   ``exchange_wait`` duration (mean over workers) agrees with the matching
   committed artifact cell's ``exchange_us`` within ``--tolerance``.

    python scripts/trace_check.py trace.json --steps 8 --superstep 2 \
        --workers 4 --bench BENCH_overlap.json --net chaos-small \
        --schedule interleave --delay 400 --tolerance 0.25
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def fail(msg: str) -> None:
    print(f"[trace-check] FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace")
    ap.add_argument("--steps", type=int, default=None,
                    help="optimizer steps the traced run executed")
    ap.add_argument("--superstep", type=int, default=1)
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--bench", default=None,
                    help="BENCH_overlap.json to cross-check exchange_us")
    ap.add_argument("--net", default="chaos-small")
    ap.add_argument("--schedule", default="interleave")
    ap.add_argument("--delay", type=float, default=400.0)
    ap.add_argument("--tolerance", type=float, default=0.25)
    args = ap.parse_args()

    # 1. format
    with open(args.trace) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("no traceEvents list")
    tracks = set()
    named_procs, named_threads = set(), set()
    for ev in events:
        if "ph" not in ev or "pid" not in ev:
            fail(f"event missing ph/pid: {ev}")
        if ev["ph"] == "M":
            if ev["name"] == "process_name":
                named_procs.add(ev["pid"])
            elif ev["name"] == "thread_name":
                named_threads.add((ev["pid"], ev["tid"]))
            continue
        if "ts" not in ev:
            fail(f"event missing ts: {ev}")
        tracks.add((ev["pid"], ev.get("tid", 0)))
    for pid, tid in tracks:
        if pid not in named_procs:
            fail(f"pid {pid} has no process_name metadata")
        if (pid, tid) not in named_threads:
            fail(f"track {(pid, tid)} has no thread_name metadata")
    spans = [ev for ev in events if ev["ph"] == "X"]
    print(f"[trace-check] {len(events)} events, {len(spans)} spans, "
          f"{len(tracks)} named tracks")

    # 2. structure
    supersteps = [ev for ev in spans if ev["name"] == "superstep"]
    exchange = defaultdict(list)    # (bucket, worker) -> spans
    waits = defaultdict(list)       # worker -> slept durations (us)
    for ev in spans:
        if ev["name"].startswith("exchange/"):
            a = ev.get("args", {})
            exchange[(a.get("bucket"), a.get("worker"))].append(ev)
        elif ev["name"].startswith("exchange_wait/"):
            waits[ev.get("args", {}).get("worker")].append(ev["dur"])
    buckets = sorted({b for b, _ in exchange})
    workers = sorted({w for _, w in exchange})
    print(f"[trace-check] {len(supersteps)} superstep spans; buckets="
          f"{buckets} workers={workers}")
    if args.steps is not None:
        want = args.steps // args.superstep
        if len(supersteps) != want:
            fail(f"expected {want} superstep spans "
                 f"(steps={args.steps}/K={args.superstep}), "
                 f"got {len(supersteps)}")
        if not exchange:
            fail("no exchange/<bucket> spans in trace")
        if args.workers is not None and len(workers) != args.workers:
            fail(f"expected exchange spans from {args.workers} workers, "
                 f"got {len(workers)}: {workers}")
        for (b, w), evs in sorted(exchange.items()):
            if len(evs) != args.steps:
                fail(f"bucket {b!r} worker {w}: {len(evs)} exchange "
                     f"spans, expected one per step ({args.steps})")
        print(f"[trace-check] every bucket x worker has exactly "
              f"{args.steps} exchange spans "
              f"({len(buckets)} buckets x {len(workers)} workers)")

    # 3. exchange_us cross-check
    if args.bench:
        if args.steps is None:
            fail("--bench needs --steps")
        with open(args.bench) as f:
            bench = json.load(f)
        cell = next((r for r in bench.get("runs", [])
                     if r["net"] == args.net
                     and r["workers"] == (args.workers or r["workers"])
                     and r["schedule"] == args.schedule
                     and r["delay_ns_per_byte"] == args.delay), None)
        if cell is None:
            fail(f"no {args.net}/N{args.workers}/{args.schedule}"
                 f"/delay{args.delay} cell in {args.bench}")
        per_worker = [sum(ds) / args.steps for ds in waits.values()]
        if not per_worker:
            fail("no exchange_wait spans to compare")
        measured = sum(per_worker) / len(per_worker)
        ref = cell["exchange_us"]
        err = abs(measured - ref) / ref
        print(f"[trace-check] per-step exchange wait {measured:.0f}us vs "
              f"committed exchange_us {ref:.0f}us "
              f"(rel err {err:.1%}, tolerance {args.tolerance:.0%})")
        if err > args.tolerance:
            fail(f"traced exchange wait disagrees with {args.bench} "
                 f"beyond {args.tolerance:.0%}")
    print("[trace-check] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
