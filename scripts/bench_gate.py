#!/usr/bin/env python
"""Perf gate over BENCH_train.json (DESIGN.md §11): compare a candidate
train-bench artifact against the committed baseline cell-by-cell, normalised
for machine speed.

Absolute steps/sec are not comparable across hosts (the committed baseline
ran elsewhere), so the gate works on RATIOS: for every cell present in both
artifacts it computes ``candidate_steps_per_s / baseline_steps_per_s``, takes
the MEDIAN ratio as the machine-speed normaliser, and flags any cell whose
ratio falls below ``median * (1 - tolerance)`` — i.e. a cell that regressed
relative to its peers, which a uniformly slower/faster machine cannot cause.

    python scripts/bench_gate.py --baseline BENCH_train.json \
        --candidate /tmp/bench/BENCH_train.json --tolerance 0.5

Exit 1 lists the offending cells.  Cells only in one artifact (quick runs
measure a subset) are ignored.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys


def _cells(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for r in doc.get("runs", []):
        out[(r["net"], bool(r["use_kernel"]), r["superstep"])] = \
            float(r["steps_per_s"])
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_train.json")
    ap.add_argument("--candidate", required=True,
                    help="freshly measured BENCH_train.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed per-cell shortfall below the median "
                         "ratio (0.5 = a cell may be up to 50%% slower "
                         "than the machine-speed-normalised expectation)")
    args = ap.parse_args()

    base, cand = _cells(args.baseline), _cells(args.candidate)
    shared = sorted(set(base) & set(cand))
    if len(shared) < 2:
        print(f"[bench-gate] only {len(shared)} shared cell(s) — need >=2 "
              f"for a median normaliser; skipping gate")
        return 0
    ratios = {k: cand[k] / base[k] for k in shared}
    med = statistics.median(ratios.values())
    floor = med * (1.0 - args.tolerance)
    bad = [(k, r) for k, r in ratios.items() if r < floor]
    print(f"[bench-gate] {len(shared)} shared cells, median ratio "
          f"{med:.3f}, floor {floor:.3f} (tolerance {args.tolerance})")
    for (net, kern, k), r in sorted(ratios.items()):
        mark = "  REGRESSED" if r < floor else ""
        print(f"  {net}/{'kernel' if kern else 'xla'}/K{k}: "
              f"{r:.3f}{mark}")
    if bad:
        print(f"[bench-gate] FAIL: {len(bad)} cell(s) below the "
              f"normalised floor", file=sys.stderr)
        return 1
    print("[bench-gate] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
