#!/usr/bin/env bash
# Tier-1 gate: full test suite + quick kernel benchmark (writes
# BENCH_kernels.json so kernel perf regressions show up in review).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
python -m benchmarks.run --quick --only kernels
