"""End-to-end driver: pretrain a ~100M-param dense LM for a few hundred
steps with the full production stack — config system, data pipeline, CHAOS
sync, AdamW, checkpointing, straggler watchdog.

CPU-friendly default (~45M params, 300 steps); pass --full-100m for the
bigger run if you have time.

    PYTHONPATH=src python examples/llm_pretrain.py [--steps 300] [--sync chaos]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.types import ArchConfig
import repro.configs as C
from repro.launch import train as T


def make_cfg(full: bool) -> ArchConfig:
    if full:  # ~103M params
        return ArchConfig(
            name="repro-lm-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=8192,
            qk_norm=True, scan_layers=True, remat=False,
            param_dtype="float32")
    return ArchConfig(  # ~45M params: same family, CPU-budget friendly
        name="repro-lm-45m", family="dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_head=64, d_ff=1408, vocab_size=8192,
        qk_norm=True, scan_layers=True, remat=False, param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--sync", default="chaos")
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_llm_ckpt")
    args = ap.parse_args()

    cfg = make_cfg(args.full_100m)
    n = cfg.param_count()
    print(f"model: {cfg.name} ({n/1e6:.0f}M params), sync={args.sync}")

    # register the config on the fly so the standard driver can use it
    import repro.configs as CF
    import types as _t
    mod = _t.ModuleType("custom")
    mod.CONFIG = cfg
    mod.smoke_config = lambda: cfg
    CF._ALIAS[cfg.name] = cfg.name
    sys.modules[f"repro.configs.{cfg.name}"] = mod

    state, losses = T.train(cfg.name, args.steps, args.sync, batch=4,
                            seq=256, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                            base_lr=1e-3, log_every=20)
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.3 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
