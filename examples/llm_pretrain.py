"""End-to-end driver: pretrain a ~100M-param dense LM for a few hundred
steps with the full production stack — config system, data pipeline, CHAOS
sync, AdamW, checkpointing, straggler watchdog.

CPU-friendly default (~45M params, 300 steps); pass --full-100m for the
bigger run if you have time.

    PYTHONPATH=src python examples/llm_pretrain.py [--steps 300] [--sync chaos]

Worker-mesh route (CHAOS at transformer scale, DESIGN.md §10): N worker
instances over forced host devices, the chunked layer stack exchanged
bucket-by-bucket with the paper's layerwise update rule, attention through
the trainable Pallas flash kernel:

    python examples/llm_pretrain.py --steps 8 --superstep 4 --workers 2 \
        --layerwise --interleave --use-kernel --staleness 1
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _force_host_devices():
    """The worker-mesh route needs N visible devices; XLA reads the flag at
    jax-import time, so peek argv BEFORE importing jax."""
    if "--workers" not in sys.argv:
        return
    n = int(sys.argv[sys.argv.index("--workers") + 1])
    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


_force_host_devices()

import jax
import numpy as np

from repro.core.types import ArchConfig
import repro.configs as C
from repro.launch import train as T


def make_cfg(full: bool) -> ArchConfig:
    if full:  # ~103M params
        return ArchConfig(
            name="repro-lm-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=8192,
            qk_norm=True, scan_layers=True, remat=False,
            param_dtype="float32")
    return ArchConfig(  # ~45M params: same family, CPU-budget friendly
        name="repro-lm-45m", family="dense", n_layers=8, d_model=512,
        n_heads=8, n_kv_heads=4, d_head=64, d_ff=1408, vocab_size=8192,
        qk_norm=True, scan_layers=True, remat=False, param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--sync", default="chaos")
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_llm_ckpt")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--superstep", type=int, default=1,
                    help="steps per compiled scan dispatch (K)")
    ap.add_argument("--workers", type=int, default=None,
                    help="CHAOS worker-mesh route: N worker instances over "
                         "forced host devices (the flag is injected before "
                         "jax initialises)")
    ap.add_argument("--logical-shards", type=int, default=4,
                    help="fixed micro-shard count on the worker route; must "
                         "divide --batch, any --workers dividing it is "
                         "bit-identical for bsp/chaos")
    ap.add_argument("--staleness", type=int, default=1,
                    help="chaos staleness tau (0 degenerates exactly to bsp)")
    ap.add_argument("--layerwise", action="store_true",
                    help="paper's per-bucket non-instant updates: the "
                         "chunked layer stack is exchanged bucket-by-bucket")
    ap.add_argument("--interleave", action="store_true",
                    help="fire each chunk bucket's exchange during backprop "
                         "(worker route, DESIGN.md §8/§10)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="attention through the trainable Pallas flash "
                         "kernel (kernels/flash_attention.py)")
    ap.add_argument("--compress", action="store_true",
                    help="bf16 gradient exchange with error feedback")
    ap.add_argument("--layer-chunk", type=int, default=None,
                    help="layer-stack chunk size (default: 2 when "
                         "--layerwise, else the single-stack scan layout)")
    ap.add_argument("--optim", default="auto",
                    choices=["auto", "sgd", "momentum", "adamw"],
                    help="optimizer (auto -> adamw; adamw's whole-tree "
                         "grad clip keeps --interleave on the "
                         "collect-then-walk schedule — pass sgd for the "
                         "true mid-backprop interleaved exchange)")
    args = ap.parse_args()

    layer_chunk = args.layer_chunk
    if layer_chunk is None and args.layerwise:
        layer_chunk = 2  # embed -> n_layers/2 chunk buckets -> head

    cfg = make_cfg(args.full_100m)
    n = cfg.param_count()
    print(f"model: {cfg.name} ({n/1e6:.0f}M params), sync={args.sync}, "
          f"workers={args.workers}, layer_chunk={layer_chunk}, "
          f"kernel={args.use_kernel}")

    # register the config on the fly so the standard driver can use it
    import repro.configs as CF
    import types as _t
    mod = _t.ModuleType("custom")
    mod.CONFIG = cfg
    mod.smoke_config = lambda: cfg
    CF._ALIAS[cfg.name] = cfg.name
    sys.modules[f"repro.configs.{cfg.name}"] = mod

    state, losses = T.train(cfg.name, args.steps, args.sync,
                            batch=args.batch, seq=256,
                            ckpt_dir=args.ckpt_dir, ckpt_every=100,
                            base_lr=1e-3, log_every=20,
                            superstep=args.superstep,
                            use_kernel=args.use_kernel,
                            workers=args.workers,
                            logical_shards=args.logical_shards,
                            staleness=args.staleness,
                            layerwise=args.layerwise,
                            interleave=args.interleave,
                            compress=args.compress,
                            layer_chunk=layer_chunk, optim=args.optim)
    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'LEARNED' if last < first - 0.3 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
