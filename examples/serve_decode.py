"""Serving example across three architecture families (dense GQA, MLA
compressed cache, attention-free RWKV state): a static batch through the
batched-prefill path, then a continuous-batching run under a seeded
Poisson trace (DESIGN.md §9).

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve, serve_trace

if __name__ == "__main__":
    for arch in ("qwen3-14b", "minicpm3-4b", "rwkv6-1.6b"):
        print(f"== {arch} (reduced config) ==")
        serve(arch, batch=2, prompt_len=12, gen=12, max_seq=32)
    print("== continuous batching (qwen3-14b, Poisson trace) ==")
    finished, counters, times = serve_trace(
        "qwen3-14b", slots=2, requests=4, rate=1.0, prompt_lens=(4, 10),
        gen=6, max_seq=32)
    toks = sum(f.prompt_len + len(f.tokens) for f in finished)
    print(f"finished {len(finished)} requests, {toks} tokens; dispatches: "
          f"{counters['prefill_dispatch']} prefill + "
          f"{counters['decode_dispatch']} decode")
