"""Batched serving example: prefill + decode with KV/state caches across
three different architecture families (dense GQA, MLA compressed cache,
attention-free RWKV state).

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve

if __name__ == "__main__":
    for arch in ("qwen3-14b", "minicpm3-4b", "rwkv6-1.6b"):
        print(f"== {arch} (reduced config) ==")
        serve(arch, batch=2, prompt_len=12, gen=12, max_seq=32)
