"""Quickstart: train the paper's small CNN on (synthetic) MNIST with the
CHAOS parallelization scheme and verify accuracy parity with BSP.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

import repro.configs as C
from repro.core.chaos import SyncConfig
from repro.data.mnist import splits
from repro.data.pipeline import ImagePipeline
from repro.models.api import get_ops
from repro.optim import sgd
from repro.train.step import init_train_state, make_train_step


def train(sync_mode: str, steps: int = 150):
    cfg = C.get("chaos-small")
    sync = SyncConfig(mode=sync_mode)
    opt = sgd(lambda s: 0.05)
    step = jax.jit(make_train_step(cfg, sync, opt))
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    (xi, yi), _, (xt, yt) = splits(2048, 128, 512, seed=0)
    pipe = ImagePipeline(xi, yi, batch=32)
    for t in range(steps):
        state, metrics = step(state, pipe.batch_at(t))
        if t % 25 == 0:
            print(f"  [{sync_mode}] step {t:4d} loss={float(metrics['loss']):.3f} "
                  f"err={float(metrics['error_rate']):.3f}")
    ops = get_ops(cfg)
    _, m = ops.loss(state["params"], {"images": xt, "labels": yt})
    return float(m["error_rate"])


if __name__ == "__main__":
    print("== BSP (paper strategy B baseline) ==")
    err_bsp = train("bsp")
    print("== CHAOS (delayed, overlap-friendly sync) ==")
    err_chaos = train("chaos")
    print(f"\ntest error: bsp={err_bsp:.3f}  chaos={err_chaos:.3f} "
          f"(paper Result 4: parity)")
