"""Paper reproduction: the CHAOS speedup/scalability study.

Prints, side by side:
  - Fig 7/8-style speedup curves predicted by the paper's performance
    model (Section 5.2, Listing 2) and Table 8 (480..3840 threads),
  - the MEASURED worker-scaling curves from ``BENCH_scaling.json``
    (``benchmarks/run.py --only scaling``): the worker-mesh superstep
    path run at 1/2/4/8 workers for the three Table-2 nets x three sync
    modes, with the model's prediction for the same worker count,
  - a live 4-worker CHAOS run through the production driver
    (``repro.launch.train --workers 4``) on forced host devices.

    PYTHONPATH=src python examples/chaos_speedup.py
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, SRC)

from repro.core import perf_model as pm


def model_curves():
    print("== speedup vs Phi 1T (performance model, Listing 2) ==")
    print(f"{'threads':>8} {'small':>8} {'medium':>8} {'large':>8}")
    for p in (15, 30, 60, 120, 180, 240, 244):
        row = [f"{pm.predict_speedup(a, p):8.1f}"
               for a in ("small", "medium", "large")]
        print(f"{p:8d} " + " ".join(row))
    print("paper Result 3: up to 103x vs Phi 1T\n")

    print("== Table 8: predicted minutes beyond hardware threads ==")
    t8 = pm.table8()
    for arch in ("small", "medium", "large"):
        cells = "  ".join(f"{p}T={t8[arch][p]:6.1f}min"
                          for p in (480, 960, 1920, 3840))
        paper = "  ".join(f"{pm.PAPER_TABLE8[arch][p]}" for p in
                          (480, 960, 1920, 3840))
        print(f"{arch:7s} pred: {cells}")
        print(f"{'':7s} paper: {paper}")


def measured_curves(path=None):
    """Measured steps/sec + speedup per worker count (BENCH_scaling.json)
    printed next to the performance model's prediction for the same worker
    count — the paper's measured-vs-modeled methodology (Figs 11-13)."""
    path = path or os.path.join(ROOT, "BENCH_scaling.json")
    print("\n== measured worker scaling (BENCH_scaling.json) ==")
    if not os.path.exists(path):
        print(f"  {path} not found — generate it with:\n"
              f"    PYTHONPATH=src python -m benchmarks.run --only scaling")
        return
    with open(path) as f:
        data = json.load(f)
    runs = [r for r in data.get("runs", []) if not r.get("use_kernel")]
    if not runs:
        print("  no xla-path runs recorded")
        return
    print("  (forced host devices share one CPU: measured speedup shows "
          "the\n   harness + overhead trend; 'model' is the paper's "
          "prediction at N threads)")
    for net in ("chaos-small", "chaos-medium", "chaos-large"):
        net_runs = [r for r in runs if r["net"] == net]
        if not net_runs:
            continue
        print(f"\n  {net}")
        print(f"  {'mode':>9s} " + " ".join(
            f"{'N=' + str(n):>16s}"
            for n in sorted({r['workers'] for r in net_runs})))
        for mode in ("bsp", "chaos", "localsgd"):
            cells = []
            for r in sorted((r for r in net_runs if r["mode"] == mode),
                            key=lambda r: r["workers"]):
                cells.append(f"{r['steps_per_s']:6.2f}st/s "
                             f"{r['speedup_vs_1']:4.2f}x")
            if cells:
                print(f"  {mode:>9s} " + " ".join(f"{c:>16s}"
                                                  for c in cells))
        model = " ".join(
            f"{pm.predict_speedup(net.split('-')[1], n):15.2f}x"
            for n in sorted({r['workers'] for r in net_runs}))
        print(f"  {'model':>9s} {model}")


def staleness_curves(path=None):
    """Measured staleness-τ curves (BENCH_staleness.json): final error and
    steps/sec vs τ per net and worker count — the paper's Result 1-2 claim
    (accuracy not significantly degraded by asynchronous stale updates)
    next to the Listing-2 speedup model's prediction for the same worker
    count.  τ=0 IS bsp (the strategy registry resolves it), so its column
    is the synchronous baseline."""
    path = path or os.path.join(ROOT, "BENCH_staleness.json")
    print("\n== measured staleness-tau curves (BENCH_staleness.json) ==")
    if not os.path.exists(path):
        print(f"  {path} not found — generate it with:\n"
              f"    PYTHONPATH=src python -m benchmarks.run "
              f"--only staleness")
        return
    with open(path) as f:
        data = json.load(f)
    runs = data.get("runs", [])
    if not runs:
        print("  no runs recorded")
        return
    print("  (error columns are hardware-independent; steps/s on forced "
          "host\n   devices shares one CPU — see the artifact's note)")
    lw_runs = [r for r in runs if r.get("layerwise")]
    if lw_runs:
        print("  layerwise (per-bucket exchange, DESIGN.md §6) rows:")
        for r in lw_runs:
            s = r.get("speedup_vs_batched", float("nan"))
            print(f"    {r['net']:>12s} tau={r['tau']} N={r['workers']}: "
                  f"err={r['final_error']:.3f} "
                  f"{r['steps_per_s']:.1f} steps/s ({s:.2f}x batched)")
    for net in ("chaos-small", "chaos-medium", "chaos-large"):
        net_runs = [r for r in runs
                    if r["net"] == net and not r.get("layerwise")]
        if not net_runs:
            continue
        taus = sorted({r["tau"] for r in net_runs})
        print(f"\n  {net} (error | steps/s per tau)")
        print(f"  {'workers':>9s} " + " ".join(
            f"{'tau=' + str(t):>16s}" for t in taus))
        for n in sorted({r["workers"] for r in net_runs}):
            cells = []
            for t in taus:
                r = next((r for r in net_runs
                          if r["tau"] == t and r["workers"] == n), None)
                cells.append(f"{r['final_error']:.3f}|"
                             f"{r['steps_per_s']:6.2f}st/s" if r else "-")
            print(f"  {'N=' + str(n):>9s} " + " ".join(
                f"{c:>16s}" for c in cells))
        deltas = [abs(r.get("error_delta_vs_tau0", 0.0)) for r in net_runs
                  if r["tau"] > 0]
        if deltas:
            print(f"  max |error - tau0 error| = {max(deltas):.4f} "
                  f"(paper claim: not significantly degraded)")


def measured_workers():
    """Live demo: 4 CHAOS workers through the production driver's
    worker-mesh route (shard_map superstep; forced host devices)."""
    print("\n== live: 4 CHAOS workers via repro.launch.train ==")
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "chaos-small",
         "--steps", "12", "--superstep", "4", "--workers", "4",
         "--sync", "chaos"],
        env=env, capture_output=True, text=True, timeout=900)
    print(out.stdout)
    if out.returncode != 0:
        print(f"driver FAILED (rc={out.returncode}):\n{out.stderr[-2000:]}")


if __name__ == "__main__":
    model_curves()
    measured_curves()
    staleness_curves()
    measured_workers()
