"""Paper reproduction: the CHAOS speedup/scalability study.

Reproduces, from the performance model (Section 5.2) + measured worker-model
runs on forced host devices:
  - Fig 7/8-style speedup curves (vs 1 Xeon Phi thread),
  - Table 8 (480..3840-thread predictions),
  - Result 3 headline numbers,
  - a *measured* multi-worker CHAOS run (4 host devices) demonstrating the
    worker model (per-replica instances, delayed gradient exchange).

    PYTHONPATH=src python examples/chaos_speedup.py
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)

from repro.core import perf_model as pm


def model_curves():
    print("== speedup vs Phi 1T (performance model, Listing 2) ==")
    print(f"{'threads':>8} {'small':>8} {'medium':>8} {'large':>8}")
    for p in (15, 30, 60, 120, 180, 240, 244):
        row = [f"{pm.predict_speedup(a, p):8.1f}"
               for a in ("small", "medium", "large")]
        print(f"{p:8d} " + " ".join(row))
    print("paper Result 3: up to 103x vs Phi 1T\n")

    print("== Table 8: predicted minutes beyond hardware threads ==")
    t8 = pm.table8()
    for arch in ("small", "medium", "large"):
        cells = "  ".join(f"{p}T={t8[arch][p]:6.1f}min"
                          for p in (480, 960, 1920, 3840))
        paper = "  ".join(f"{pm.PAPER_TABLE8[arch][p]}" for p in
                          (480, 960, 1920, 3840))
        print(f"{arch:7s} pred: {cells}")
        print(f"{'':7s} paper: {paper}")


def measured_workers():
    print("\n== measured: 4 CHAOS workers (forced host devices) ==")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, time
        from repro.core.chaos import SyncConfig, worker_train_fn, \\
            replicate_for_workers, zeros_like_f32
        from repro.launch.mesh import make_host_mesh
        import repro.configs as C
        from repro.models.api import get_ops
        from repro.data.mnist import make_dataset

        cfg = C.get("chaos-small")
        ops = get_ops(cfg)
        n = 4
        mesh = make_host_mesh(n)
        imgs, labels = make_dataset(n * 16 * 12, seed=0)
        params = ops.init(jax.random.key(0))
        state = {"params": replicate_for_workers(params, n),
                 "prev_grad": replicate_for_workers(zeros_like_f32(params), n),
                 "step": jnp.zeros((n,), jnp.int32)}
        fn = worker_train_fn(ops.loss, lambda s: 0.05, SyncConfig("chaos"), mesh)
        for t in range(12):
            lo = t * n * 16
            b = {"images": imgs[lo:lo+n*16].reshape(n, 16, 29, 29, 1),
                 "labels": labels[lo:lo+n*16].reshape(n, 16)}
            state, m = fn(state, b)
            print(f"  step {t:2d} worker-mean loss={float(m['loss']):.3f}")
    """)
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    print(out.stdout or out.stderr[-2000:])


if __name__ == "__main__":
    model_curves()
    measured_workers()
