"""Observability subsystem (DESIGN.md §11): tracer export format, stamp
pairing, metrics bus semantics, the PR-6 metrics-out schema fold, and the
two overhead pins — obs off is bit-exact, obs on costs <= 2%."""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import JsonlSink, MetricsBus, Tracer, get_tracer, set_tracer
from repro.obs import trace as obs_trace

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# tracer: chrome export format
# ---------------------------------------------------------------------------
def test_tracer_chrome_export(tmp_path):
    tr = Tracer("train")
    with tr.span("superstep", step_start=0, k=2):
        with tr.span("checkpoint", step=1):
            pass
    tr.instant("fault", kind="kill")
    tr.counter("watchdog/superstep_s", 0.25)
    tr.complete("request/7", 100.0, 250.0, process="serve", thread="slot0",
                rid=7)
    path = tmp_path / "trace.json"
    tr.write(str(path))

    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    # every non-metadata event's track carries metadata
    procs = {e["pid"] for e in evs if e.get("name") == "process_name"}
    threads = {(e["pid"], e["tid"]) for e in evs
               if e.get("name") == "thread_name"}
    for e in evs:
        if e["ph"] == "M":
            continue
        assert e["pid"] in procs
        assert (e["pid"], e.get("tid", 0)) in threads
    by_name = {e["name"]: e for e in evs if e["ph"] != "M"}
    sup, ckpt = by_name["superstep"], by_name["checkpoint"]
    assert sup["ph"] == ckpt["ph"] == "X"
    # nesting: the inner span lies within the outer on the same track
    assert (sup["pid"], sup["tid"]) == (ckpt["pid"], ckpt["tid"])
    assert sup["ts"] <= ckpt["ts"]
    assert ckpt["ts"] + ckpt["dur"] <= sup["ts"] + sup["dur"] + 1e-3
    assert by_name["fault"]["ph"] == "i"
    assert by_name["watchdog/superstep_s"]["ph"] == "C"
    assert by_name["request/7"]["dur"] == pytest.approx(150.0)
    # the sibling JSONL has one event per line
    lines = (tmp_path / "trace.jsonl").read_text().splitlines()
    assert len(lines) == len(evs)
    assert all(json.loads(ln) for ln in lines)


def test_tracer_stamp_pairing():
    """bucket_issue/bucket_gate inside a jitted function pair into
    exchange/exchange_wait spans, and an injected delay is actually slept
    by the gate (the PR-7 deadline contract)."""
    tr = Tracer("train")

    @jax.jit
    def f(x):
        g = x * 2.0
        tok = tr.bucket_issue(g, "conv0", delay_ms=30.0,
                              args={"bytes": 128, "tau": 0})
        g = tr.bucket_gate(g, tok, g, "conv0")
        return g

    x = jnp.ones((4,))
    t0 = time.monotonic()
    y1 = jax.block_until_ready(f(x))
    y2 = jax.block_until_ready(f(x))
    dt = time.monotonic() - t0
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_allclose(np.asarray(y1), 2.0)  # value-preserving
    assert dt >= 0.05                                # 2 x 30ms slept

    spans = tr.finalize()
    ex = [e for e in spans if e["name"] == "exchange/conv0"]
    wait = [e for e in spans if e["name"] == "exchange_wait/conv0"]
    assert len(ex) == len(wait) == 2
    for e in ex + wait:
        assert e["args"]["bucket"] == "conv0"
        assert e["args"]["bytes"] == 128
    for w in wait:
        assert w["args"]["slept_ms"] == pytest.approx(30.0, rel=0.5)
        assert w["dur"] >= 25e3                      # us


def test_tracer_global_install():
    assert get_tracer() is None
    with obs_trace.span("noop") as t:
        assert t is None                             # no-op without tracer
    tr = Tracer()
    prev = set_tracer(tr)
    try:
        assert prev is None and get_tracer() is tr
        with obs_trace.span("superstep"):
            pass
        assert any(e["name"] == "superstep" for e in tr.to_chrome()
                   ["traceEvents"])
    finally:
        set_tracer(prev)
    assert get_tracer() is None


# ---------------------------------------------------------------------------
# metrics bus
# ---------------------------------------------------------------------------
def test_metrics_bus_summary(tmp_path):
    sink = JsonlSink(str(tmp_path / "metrics.jsonl"))
    bus = MetricsBus(sink=sink)
    bus.counter("serve/decode_dispatch")
    bus.counter("serve/decode_dispatch", 3)
    bus.gauge("train/steps_per_s", 12.5)
    for v in [0.1, 0.2, 0.3]:
        bus.observe("serve/ttft_s", v)
    bus.series("train/loss", 0, 2.5)
    bus.series("train/loss", 2, 2.3)
    bus.series("train/loss", 2, 2.2)                 # same step overwrites
    bus.event("resize", **{"from": 4, "to": 3})
    bus.flush(step=2)
    bus.close()

    s = bus.summary()
    assert s["counters"]["serve/decode_dispatch"] == 4
    assert s["gauges"]["train/steps_per_s"] == 12.5
    h = s["histograms"]["serve/ttft_s"]
    assert h["count"] == 3
    assert h["mean"] == pytest.approx(0.2)
    assert h["min"] == 0.1 and h["max"] == 0.3
    assert s["series"]["train/loss"]["steps"] == [0, 2]
    assert s["series"]["train/loss"]["values"] == [2.5, 2.2]
    assert s["events"]["resize"][0]["to"] == 3
    assert bus.series_sorted("train/loss") == [2.5, 2.2]
    lines = [json.loads(ln) for ln in
             (tmp_path / "metrics.jsonl").read_text().splitlines()]
    assert lines                                     # flush wrote something


def test_metrics_out_schema(tmp_path):
    """write_metrics_out preserves the PR-6 --metrics-out contract that
    CI's preemption smoke asserts on: losses/resizes/faults/workers_final."""
    bus = MetricsBus()
    for t, v in enumerate([2.5, 2.4, 2.3, 2.2]):
        bus.series("train/loss", t, v)
    bus.event("resize", **{"from": 4, "to": 3, "path": "dense"})
    bus.event("fault", kind="kill", at=2)
    path = str(tmp_path / "metrics.json")
    bus.write_metrics_out(path, arch="chaos-small", sync="bsp", steps=4,
                          workers_final=3)
    doc = json.loads(open(path).read())
    assert doc["arch"] == "chaos-small"
    assert doc["sync"] == "bsp"
    assert doc["steps"] == 4
    assert doc["losses"] == [2.5, 2.4, 2.3, 2.2]
    assert (doc["resizes"][0]["from"], doc["resizes"][0]["to"]) == (4, 3)
    assert doc["faults"][0]["kind"] == "kill"
    assert doc["workers_final"] == 3


# ---------------------------------------------------------------------------
# overhead pins: obs off is bit-exact; obs on (bus attached) <= 2%
# ---------------------------------------------------------------------------
def _timed_train(steps, superstep, bus=None):
    from repro.launch.train import train
    t0 = time.perf_counter()
    _, losses = train("chaos-small", steps, "bsp", batch=8,
                      log_every=10_000, superstep=superstep,
                      metrics_bus=bus)
    return time.perf_counter() - t0, [float(x) for x in losses]


def test_obs_overhead_and_bit_exactness():
    steps, K = 48, 8
    _timed_train(8, 8)                               # warm compile caches
    assert get_tracer() is None                      # tracing disabled
    # min-of-attempts absorbs scheduler noise; the losses pin is hard on
    # every attempt, the <=2% steps/sec pin must hold for the best pair
    base_losses = obs_losses = None
    best_base = best_obs = float("inf")
    last_bus = None
    for _ in range(3):
        dt_b, l_b = _timed_train(steps, K)
        bus = MetricsBus()
        dt_o, l_o = _timed_train(steps, K, bus=bus)
        if base_losses is None:
            base_losses, obs_losses = l_b, l_o
        assert l_b == base_losses and l_o == obs_losses
        best_base = min(best_base, dt_b)
        best_obs = min(best_obs, dt_o)
        last_bus = bus
        if best_obs <= best_base * 1.02:
            break
    # bit-exactness: the bus only OBSERVES host-side values — losses from
    # the obs run are bit-identical to the no-obs run
    assert obs_losses == base_losses
    s = last_bus.summary()
    assert s["series"]["train/loss"]["values"] == base_losses
    assert s["gauges"]["train/steps_per_s"] > 0
    assert best_obs <= best_base * 1.02, (
        f"obs-on train {best_obs:.3f}s vs {best_base:.3f}s "
        f"(+{(best_obs / best_base - 1) * 100:.1f}%, budget 2%)")


# ---------------------------------------------------------------------------
# 4-worker traced driver run: structure + exchange_us cross-check
# ---------------------------------------------------------------------------
def test_traced_interleave_driver(tmp_path):
    """The acceptance path: --trace-out on the 4-worker interleave driver
    with injected collective latency yields per-bucket exchange spans for
    every bucket x step x worker, and their summed gate-wait agrees with
    the committed BENCH_overlap.json cell within 25%."""
    trace_path = str(tmp_path / "trace.json")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "chaos-small",
         "--steps", "8", "--superstep", "2", "--workers", "4",
         "--sync", "bsp", "--layerwise", "--interleave",
         "--collective-delay", "400", "--trace-out", trace_path],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-4000:]

    root = os.path.join(os.path.dirname(__file__), "..")
    check = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "trace_check.py"),
         trace_path, "--steps", "8", "--superstep", "2", "--workers", "4",
         "--bench", os.path.join(root, "BENCH_overlap.json"),
         "--net", "chaos-small", "--schedule", "interleave",
         "--delay", "400", "--tolerance", "0.25"],
        capture_output=True, text=True, timeout=120)
    assert check.returncode == 0, (check.stdout + check.stderr)[-4000:]
    assert "OK" in check.stdout


# ---------------------------------------------------------------------------
# watchdog gauges
# ---------------------------------------------------------------------------
def test_watchdog_exports_observations():
    from repro.launch.train import StragglerWatchdog
    bus, tr = MetricsBus(), Tracer("train")
    wd = StragglerWatchdog(warmup=0, bus=bus, tracer=tr)
    for step in range(10):
        assert not wd.observe(step, 0.1)
    assert wd.observe(10, 0.9)                       # straggler
    s = bus.summary()
    h = s["histograms"]["watchdog/superstep_s"]
    assert h["count"] == 11                          # every observation
    assert s["gauges"]["watchdog/superstep_s"] == pytest.approx(0.9)
    assert s["events"]["straggler"][0]["step"] == 10
    evs = tr.to_chrome()["traceEvents"]
    assert any(e["name"] == "watchdog/superstep_s" and e["ph"] == "C"
               for e in evs)
    assert any(e["name"] == "straggler" and e["ph"] == "i" for e in evs)
