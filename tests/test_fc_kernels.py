"""Fused Pallas FC + softmax-CE kernel validation (kernels/fc.py): forward
and ``jax.grad`` parity vs the XLA reference path (plain + mixed precision),
autotune integration, and the whole-train-step launch-count contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import conv2d as CK
from repro.kernels import fc as FK
from repro.kernels import ops as kops

# (B, Din, Dout) — the paper nets' FC shapes plus a lane-unfriendly odd one
FC_SHAPES = [
    (8, 90, 50),     # small: 10 maps * 3x3 -> FC50
    (8, 50, 10),     # small output layer
    (4, 360, 150),   # medium-ish tail
    (6, 37, 11),     # nothing divides nicely
]


@pytest.mark.parametrize("B,Din,Dout", FC_SHAPES)
def test_fc_fwd_matches_xla(B, Din, Dout):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    x = jax.random.normal(k1, (B, Din), jnp.float32)
    w = jax.random.normal(k2, (Din, Dout), jnp.float32) * 0.1
    b = jax.random.normal(k3, (Dout,), jnp.float32) * 0.1
    np.testing.assert_allclose(kops.fc_bias(x, w, b), x @ w + b,
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(kops.fc_bias_tanh(x, w, b),
                               jnp.tanh(x @ w + b), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("bb,db", [(1, None), (2, 8), (4, 2), (8, None)])
def test_fc_fwd_block_sweep(bb, db):
    """Any divisor blocking must be numerically identical to whole-array."""
    k1, k2 = jax.random.split(jax.random.key(1))
    x = jax.random.normal(k1, (8, 64), jnp.float32)
    w = jax.random.normal(k2, (64, 16), jnp.float32) * 0.1
    got = FK.fc_fwd(x, w, batch_block=bb, dout_block=db, interpret=True)
    np.testing.assert_allclose(got, x @ w, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("B,Din,Dout", FC_SHAPES[:2])
def test_fc_grad_parity_vs_xla(B, Din, Dout):
    """jax.grad through the fused custom VJP == grad through plain XLA."""
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    x = jax.random.normal(k1, (B, Din), jnp.float32)
    w = jax.random.normal(k2, (Din, Dout), jnp.float32) * 0.1
    b = jax.random.normal(k3, (Dout,), jnp.float32) * 0.1
    for fused, ref in [
        (kops.fc_bias_tanh, lambda x, w, b: jnp.tanh(x @ w + b)),
        (kops.fc_bias, lambda x, w, b: x @ w + b),
    ]:
        g1 = jax.grad(lambda *a: jnp.sum(jnp.cos(fused(*a))), (0, 1, 2))(
            x, w, b)
        g2 = jax.grad(lambda *a: jnp.sum(jnp.cos(ref(*a))), (0, 1, 2))(
            x, w, b)
        for a_, b_ in zip(g1, g2):
            np.testing.assert_allclose(a_, b_, atol=1e-4, rtol=1e-4)


def test_fc_bwd_cross_step_accumulation():
    """dw/db accumulate across batch-grid steps in fp32 scratch: with
    batch_block < B the fused backward must equal the whole-batch result
    (the conv-dw regression, FC flavour)."""
    k1, k2, k3 = jax.random.split(jax.random.key(3), 3)
    x = jax.random.normal(k1, (8, 32), jnp.float32)
    w = jax.random.normal(k2, (32, 12), jnp.float32) * 0.1
    dy = jax.random.normal(k3, (8, 12), jnp.float32)
    want_dw = x.T @ dy
    want_db = dy.sum(0)
    for bb in (1, 2, 4, 8):
        dx, dw, db = FK.fc_bwd_fused(x, dy, w, batch_block=bb,
                                     interpret=True)
        np.testing.assert_allclose(dw, want_dw, atol=1e-4, rtol=1e-4,
                                   err_msg=f"bb={bb}")
        np.testing.assert_allclose(db, want_db, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(dx, dy @ w.T, atol=1e-4, rtol=1e-4)


def test_fc_mixed_precision_dtypes():
    """bf16 activations/weights with an fp32 bias (standard mixed-precision
    layout): fp32 accumulation inside, per-operand dtypes outside."""
    k1, k2, k3 = jax.random.split(jax.random.key(4), 3)
    x = jax.random.normal(k1, (8, 64), jnp.float32).astype(jnp.bfloat16)
    w = (jax.random.normal(k2, (64, 16), jnp.float32) * 0.1).astype(
        jnp.bfloat16)
    b = jax.random.normal(k3, (16,), jnp.float32) * 0.1
    y = kops.fc_bias_tanh(x, w, b)
    assert y.dtype == jnp.bfloat16
    want = jnp.tanh(x.astype(jnp.float32) @ w.astype(jnp.float32) + b)
    np.testing.assert_allclose(y.astype(jnp.float32), want, atol=5e-2,
                               rtol=5e-2)
    grads = jax.grad(lambda x, w, b: jnp.sum(
        kops.fc_bias_tanh(x, w, b).astype(jnp.float32)), (0, 1, 2))(x, w, b)
    assert grads[0].dtype == jnp.bfloat16
    assert grads[1].dtype == jnp.bfloat16
    assert grads[2].dtype == jnp.float32
    ref = jax.grad(lambda x, w, b: jnp.sum(jnp.tanh(
        x.astype(jnp.float32) @ w.astype(jnp.float32) + b)), (0, 1, 2))(
        x, w, b)
    for a_, b_ in zip(grads, ref):
        np.testing.assert_allclose(a_.astype(jnp.float32),
                                   b_.astype(jnp.float32), atol=8e-2,
                                   rtol=8e-2)


# ---------------------------------------------------------------------------
# Fused softmax-cross-entropy
# ---------------------------------------------------------------------------
def _xent_ref(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - ll


@pytest.mark.parametrize("B,C", [(8, 10), (4, 33), (16, 10)])
def test_softmax_xent_value_and_grad(B, C):
    k1, k2 = jax.random.split(jax.random.key(5))
    logits = jax.random.normal(k1, (B, C), jnp.float32) * 3.0
    labels = jax.random.randint(k2, (B,), 0, C)
    np.testing.assert_allclose(kops.softmax_xent(logits, labels),
                               _xent_ref(logits, labels), atol=1e-5,
                               rtol=1e-5)
    g1 = jax.grad(lambda l: jnp.mean(kops.softmax_xent(l, labels)))(logits)
    g2 = jax.grad(lambda l: jnp.mean(_xent_ref(l, labels)))(logits)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-5)


def test_softmax_xent_dlogits_is_softmax_minus_onehot():
    k1, k2 = jax.random.split(jax.random.key(6))
    logits = jax.random.normal(k1, (8, 10), jnp.float32)
    labels = jax.random.randint(k2, (8,), 0, 10)
    _, dl = FK.softmax_xent_fwd(logits, labels, interpret=True)
    want = jax.nn.softmax(logits, -1) - jax.nn.one_hot(labels, 10)
    np.testing.assert_allclose(dl, want, atol=1e-5, rtol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    """The in-kernel max-subtraction must keep large logits finite."""
    logits = jnp.array([[1e4, -1e4, 0.0], [500.0, 499.0, -500.0]],
                       jnp.float32)
    labels = jnp.array([0, 1], jnp.int32)
    loss = kops.softmax_xent(logits, labels)
    assert np.isfinite(np.asarray(loss)).all()
    np.testing.assert_allclose(loss, _xent_ref(logits, labels), atol=1e-4)


# ---------------------------------------------------------------------------
# Whole-train-step integration: launch count + grads through the full tail
# ---------------------------------------------------------------------------
def test_full_cnn_step_launch_count_with_fc_kernels():
    """With use_kernel=True a chaos-small train step must hit EXACTLY:
    2 launches per conv layer (fused fwd + fused bwd), 2 per pool layer,
    2 per FC layer, and 1 for softmax-CE (its backward reuses the saved
    dlogits — zero extra launches)."""
    import repro.configs as C
    from repro.models import cnn
    from repro.models import layers as L
    cfg = C.get("chaos-small")
    params = cnn.build_params(cfg, L.InitFactory(jax.random.key(0),
                                                 jnp.float32))
    batch = {"images": jax.random.uniform(jax.random.key(1), (4, 29, 29, 1)),
             "labels": jax.random.randint(jax.random.key(2), (4,), 0, 10)}
    n_conv = sum(1 for s in cfg.cnn_layers if s[0] == "conv")
    n_pool = sum(1 for s in cfg.cnn_layers if s[0] == "pool")
    n_fc = sum(1 for s in cfg.cnn_layers if s[0] == "fc") + 1  # + output fc
    with CK.launch_trace() as rec:
        jax.grad(lambda p: cnn.loss_fn(p, batch, cfg, use_kernel=True)[0])(
            params)
    assert rec.count("fc_fwd") == n_fc
    assert rec.count("fc_bwd_fused") == n_fc
    assert rec.count("softmax_xent") == 1
    assert rec.count("conv2d_fwd") == n_conv
    assert rec.count("conv2d_bwd_fused") == n_conv
    assert rec.count("maxpool2d_fwd") == n_pool
    assert rec.count("maxpool2d_bwd") == n_pool
    assert len(rec) == 2 * (n_conv + n_pool + n_fc) + 1, rec


def test_full_cnn_grads_kernel_tail_vs_xla_tail():
    """Full train-step gradients with the FC + softmax-CE kernels == the
    XLA path (the conv-only version of this lives in test_kernels.py)."""
    import repro.configs as C
    from repro.models import cnn
    from repro.models import layers as L
    cfg = C.get("chaos-small")
    params = cnn.build_params(cfg, L.InitFactory(jax.random.key(0),
                                                 jnp.float32))
    batch = {"images": jax.random.uniform(jax.random.key(1), (8, 29, 29, 1)),
             "labels": jax.random.randint(jax.random.key(2), (8,), 0, 10)}
    g1 = jax.grad(lambda p: cnn.loss_fn(p, batch, cfg, use_kernel=True)[0])(
        params)
    g2 = jax.grad(lambda p: cnn.loss_fn(p, batch, cfg, use_kernel=False)[0])(
        params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)


def test_fc_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """tune_fc_fwd persists to the JSON cache under the fc_fwd| key, the
    tuned config is never slower than the baseline on its own measurements,
    and it is numerically identical to the baseline."""
    from repro.kernels import autotune as AT
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    AT.clear_memory_cache()
    k1, k2 = jax.random.split(jax.random.key(7))
    x = jax.random.normal(k1, (8, 90), jnp.float32)
    w = jax.random.normal(k2, (90, 50), jnp.float32) * 0.1
    cfg, rep = AT.tune_fc_fwd(x, w, iters=1)
    assert rep["key"].startswith("fc_fwd|plain|")
    assert rep["best_us"] <= rep["baseline_us"]
    AT.clear_memory_cache()
    entry = AT.lookup(rep["key"])
    assert entry is not None and entry["config"] == cfg
    got = FK.fc_fwd(x, w, interpret=True, **cfg)
    np.testing.assert_allclose(got, x @ w, atol=1e-5, rtol=1e-5)
    bcfg, brep = AT.tune_fc_bwd(
        x, jax.random.normal(k1, (8, 50), jnp.float32), w, iters=1)
    assert brep["best_us"] <= brep["baseline_us"]
    assert AT.lookup(brep["key"])["config"] == bcfg
    AT.clear_memory_cache()


def test_fc_candidates_respect_vmem_budget():
    from repro.kernels import autotune as AT
    x_shape, w_shape = (64, 4096), (4096, 8192)
    cands = AT.fc_fwd_candidates(x_shape, w_shape)
    assert dict(AT.FC_BASELINE) in cands
    for cfg in cands[1:]:
        assert AT.fc_fwd_vmem_bytes(cfg, x_shape, w_shape) <= \
            AT.VMEM_BUDGET_BYTES
