import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count globally — smoke
# tests and benches must see 1 device (launch/dryrun.py sets 512 itself).
# Tests that need a few host devices spawn subprocesses (see test_chaos.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
