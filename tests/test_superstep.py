"""Superstep (scanned multi-step) execution semantics (DESIGN.md §3).

The contract: grouping K steps into one compiled ``lax.scan`` dispatch
changes WHEN the host syncs, never WHAT is computed — params, optimizer
moments, CHAOS sync state, and the step counter must come out bit-identical
to K individual dispatches, for every sync mode, and checkpoint-resume
mid-run must replay identically with K > 1.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.chaos import SyncConfig
from repro.data.mnist import make_dataset
from repro.data.pipeline import ImagePipeline, TokenPipeline
from repro.train.step import (init_train_state, make_optimizer,
                              make_superstep, make_train_step)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
MODES = ["bsp", "chaos", "localsgd"]


def _assert_states_bitexact(s1, s2, msg=""):
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32),
                                      err_msg=msg)


def _cnn_setup(mode, use_kernel=False, local_steps=2, staleness=1):
    import dataclasses
    cfg = C.get("chaos-small")
    if use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=True)
    sync = SyncConfig(mode, local_steps=local_steps, staleness=staleness)
    opt = make_optimizer(cfg, total_steps=8)
    imgs, labels = make_dataset(128, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=8)
    return cfg, sync, opt, pipe


@pytest.mark.parametrize("mode", MODES)
def test_superstep_bitexact_vs_individual_dispatches(mode):
    """K=4 scanned == 4 single-step dispatches (each a length-1 scan — the
    exact code path the driver runs at --superstep 1), bit-for-bit, and the
    (K,) loss vector matches the per-step losses bit-for-bit."""
    cfg, sync, opt, pipe = _cnn_setup(mode)
    super_fn = jax.jit(make_superstep(cfg, sync, opt))
    s1 = init_train_state(cfg, jax.random.key(0), sync, opt)
    s2 = init_train_state(cfg, jax.random.key(0), sync, opt)
    losses = []
    for t in range(4):
        s1, m = super_fn(s1, pipe.superstep_at(t, 1))
        losses.append(np.asarray(m["loss"])[0])
    s2, ms = super_fn(s2, pipe.superstep_at(0, 4))
    assert ms["loss"].shape == (4,)
    _assert_states_bitexact(s1, s2, f"mode={mode}")
    np.testing.assert_array_equal(np.asarray(ms["loss"]),
                                  np.asarray(losses, np.float32))


@pytest.mark.parametrize("mode", MODES)
def test_superstep_bitexact_vs_plain_step_kernel_path(mode):
    """Through the Pallas kernel path the scan is bit-identical even to the
    plain (non-scanned) per-step jit — the kernels compile identically
    inside and outside the scan body."""
    cfg, sync, opt, pipe = _cnn_setup(mode, use_kernel=True)
    step = jax.jit(make_train_step(cfg, sync, opt))
    super_fn = jax.jit(make_superstep(cfg, sync, opt))
    s1 = init_train_state(cfg, jax.random.key(0), sync, opt)
    s2 = init_train_state(cfg, jax.random.key(0), sync, opt)
    for t in range(4):
        s1, _ = step(s1, pipe.batch_at(t))
    s2, _ = super_fn(s2, pipe.superstep_at(0, 4))
    _assert_states_bitexact(s1, s2, f"mode={mode} kernel path")


@pytest.mark.parametrize("mode", MODES)
def test_superstep_bitexact_lm_adamw(mode):
    """Same contract for the LM family (adamw + grad clip + wsd schedule):
    plain per-step jit vs one K=4 scan."""
    cfg = C.smoke("qwen3-14b")
    sync = SyncConfig(mode, local_steps=2)
    opt = make_optimizer(cfg, total_steps=8)
    pipe = TokenPipeline(cfg.vocab_size, batch=2, seq_len=32)
    step = jax.jit(make_train_step(cfg, sync, opt))
    super_fn = jax.jit(make_superstep(cfg, sync, opt))
    s1 = init_train_state(cfg, jax.random.key(0), sync, opt)
    s2 = init_train_state(cfg, jax.random.key(0), sync, opt)
    for t in range(4):
        s1, _ = step(s1, pipe.batch_at(t))
    s2, _ = super_fn(s2, pipe.superstep_at(0, 4))
    _assert_states_bitexact(s1, s2, f"mode={mode} lm")


def test_localsgd_boundary_derives_from_step_carry():
    """localsgd τ=0 (the blocking boundary average; τ defaults to 1 = the
    τ-ring since the overlap PR) adds NO extra sync state: its K-boundary
    derives from the scan-carried step counter, and on a single replica
    (average == identity) it must match bsp bit-for-bit across boundary
    and non-boundary steps."""
    cfg, sync, opt, pipe = _cnn_setup("localsgd", local_steps=3,
                                      staleness=0)
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    assert state["sync"] == {}
    super_fn = jax.jit(make_superstep(cfg, sync, opt))
    state, _ = super_fn(state, pipe.superstep_at(0, 5))
    assert int(state["step"]) == 5
    cfg_b, sync_b, opt_b, _ = _cnn_setup("bsp")
    bsp_fn = jax.jit(make_superstep(cfg_b, sync_b, opt_b))
    s_bsp = init_train_state(cfg_b, jax.random.key(0), sync_b, opt_b)
    s_bsp, _ = bsp_fn(s_bsp, pipe.superstep_at(0, 5))
    _assert_states_bitexact(state["params"], s_bsp["params"],
                            "single-replica localsgd == bsp")


def test_superstep_batches_match_individual_batches():
    """pipeline.superstep_at slice i must be bit-identical to batch_at(i)
    for both pipeline families (resume == replay at any K)."""
    imgs, labels = make_dataset(64, seed=0)
    for pipe in (ImagePipeline(imgs, labels, batch=4, sample_mode="queue"),
                 ImagePipeline(imgs, labels, batch=4),
                 TokenPipeline(97, batch=3, seq_len=16)):
        stacked = pipe.superstep_at(5, 3)
        for i in range(3):
            single = pipe.batch_at(5 + i)
            for k in single:
                np.testing.assert_array_equal(stacked[k][i], single[k])


def test_queue_mode_walks_epoch_permutation_without_replacement():
    """Paper shared-queue semantics: within one epoch no sample repeats
    (workers take the next image off one global queue), and the pipeline
    stays a pure function of the step index."""
    # images tagged by index so sample identity is exactly readable
    imgs = (np.arange(64, dtype=np.float32).reshape(64, 1, 1, 1)
            * np.ones((1, 4, 4, 1), np.float32))
    labels = (np.arange(64) % 10).astype(np.int32)
    pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")
    seen = []
    for t in range(8):  # one full epoch: 64 / 8 = 8 steps
        b = pipe.batch_at(t)
        seen.extend(b["images"][:, 0, 0, 0].astype(int).tolist())
    assert sorted(seen) == list(range(64)), "epoch must cover every sample once"
    # determinism: replay gives identical batches
    b1, b2 = pipe.batch_at(3), pipe.batch_at(3)
    np.testing.assert_array_equal(b1["images"], b2["images"])


def test_cnn_arch_trains_from_driver():
    """Satellite: family=='cnn' routes through ImagePipeline — the paper's
    nets are trainable from the CLI entry point (in-process here)."""
    from repro.launch.train import train
    _, losses = train("chaos-small", steps=6, superstep=3)
    assert len(losses) == 6
    assert all(np.isfinite(losses))


def test_kill_and_restart_resumes_superstep(tmp_path):
    """Driver-level resume == replay with K>1: die at a superstep boundary,
    restart, and the final checkpoint must be bit-identical to an
    uninterrupted run's."""
    env = dict(os.environ, PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "chaos-small", "--steps", "8", "--superstep", "4",
           "--ckpt-every", "4"]
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    first = subprocess.run(cmd + ["--ckpt-dir", a, "--die-at-step", "4"],
                           capture_output=True, text=True, env=env,
                           timeout=900)
    assert first.returncode == 17, first.stderr[-2000:]
    assert "simulated preemption at step 4" in first.stdout
    second = subprocess.run(cmd + ["--ckpt-dir", a], capture_output=True,
                            text=True, env=env, timeout=900)
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed from step 4" in second.stdout
    straight = subprocess.run(cmd + ["--ckpt-dir", b], capture_output=True,
                              text=True, env=env, timeout=900)
    assert straight.returncode == 0, straight.stderr[-2000:]
    fa = np.load(os.path.join(a, "step_0000000008", "arrays.npz"))
    fb = np.load(os.path.join(b, "step_0000000008", "arrays.npz"))
    assert fa.files == fb.files
    for k in fa.files:
        np.testing.assert_array_equal(fa[k], fb[k])


def test_watchdog_bounded_and_superstep_aware():
    """Satellite: the straggler watchdog must not grow without bound and
    must widen its granularity with K."""
    from repro.launch.train import StragglerWatchdog
    wd = StragglerWatchdog(superstep=8, max_flags=16)
    assert wd.window == max(8, 200 // 8)
    for i in range(1000):
        wd.observe(i, 100.0 if i % 20 == 0 else 1.0)  # ~50 straggler spikes
    assert len(wd.flagged) > 0, "spikes must be detected"
    assert len(wd.flagged) <= 16, "flag log must be bounded"
    assert len(wd.times) <= wd.window
    wd1 = StragglerWatchdog(superstep=1)
    assert wd1.window == 200  # ~200-step horizon preserved at K=1
    # regression: windows smaller than 10 (K >= 21) must still detect —
    # the fill gate is min(10, window), not a hard 10
    wd32 = StragglerWatchdog(superstep=32)
    assert wd32.window == 8
    for i in range(100):
        wd32.observe(i, 100.0 if i % 20 == 10 else 1.0)
    assert len(wd32.flagged) > 0, "K=32 watchdog must still flag stragglers"


def test_prefetch_feed_surfaces_producer_errors():
    """A failing producer must raise in the consumer, not hang it."""
    from repro.launch.train import PrefetchFeed

    class BoomPipe:
        def superstep_at(self, step, k):
            raise ValueError("boom")

    feed = PrefetchFeed(BoomPipe(), [(0, 4)])
    with pytest.raises(RuntimeError, match="prefetch feed failed"):
        list(feed)
