"""CHAOS sync-strategy semantics (the paper's core contribution).

Worker-model tests run in a subprocess with 4 forced host devices (the env
flag must be set before jax initialises, and conftest must NOT set it
globally)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.chaos import SyncConfig, compress_grads, init_sync_state
from repro.train.step import init_train_state, make_optimizer, make_train_step

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, n_dev: int = 4):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_chaos_mode_is_delayed_bsp():
    """In the pjit path, chaos applies exactly the previous step's gradient:
    after steps t and t+1, chaos params == bsp params computed with a
    one-step-shifted gradient sequence."""
    import dataclasses
    # f32 params so the staleness buffer (stored in param dtype) is exact
    cfg = dataclasses.replace(C.smoke("qwen3-14b"), param_dtype="float32")
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    from repro.optim import sgd
    opt = sgd(lambda s: 0.01)

    bsp = make_train_step(cfg, SyncConfig("bsp"), opt)
    chaos = make_train_step(cfg, SyncConfig("chaos"), opt)

    s_b = init_train_state(cfg, jax.random.key(0), SyncConfig("bsp"), opt)
    s_c = init_train_state(cfg, jax.random.key(0), SyncConfig("chaos"), opt)

    # step 1: chaos applies zero grad; params unchanged
    s_c1, _ = jax.jit(chaos)(s_c, batch)
    p0 = jax.tree.leaves(s_c["params"])[0]
    p1 = jax.tree.leaves(s_c1["params"])[0]
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))

    # step 2 of chaos == step 1 of bsp (same batch => same gradient)
    s_c2, _ = jax.jit(chaos)(s_c1, batch)
    s_b1, _ = jax.jit(bsp)(s_b, batch)
    a = np.asarray(jax.tree.leaves(s_c2["params"])[0], np.float32)
    b = np.asarray(jax.tree.leaves(s_b1["params"])[0], np.float32)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_compress_grads_error_feedback_unbiased():
    """bf16 compression with error feedback: the cumulative applied update
    converges to the cumulative true gradient (unbiasedness)."""
    g = jnp.full((1000,), 1e-3 + 3e-8, jnp.float32)  # not bf16-representable
    residual = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(64):
        q, r = compress_grads({"g": g}, {"g": residual})
        residual = r["g"]
        total = total + q["g"].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(total), np.asarray(g) * 64,
                               rtol=1e-3)


def test_worker_model_bsp_equals_serial_sgd():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.chaos import SyncConfig, worker_train_fn, replicate_for_workers
        from repro.launch.mesh import make_host_mesh
        import repro.configs as C
        from repro.models.api import get_ops

        cfg = C.get("chaos-small")
        ops = get_ops(cfg)
        params = ops.init(jax.random.key(0))
        n = 4
        mesh = make_host_mesh(n)
        imgs = jax.random.uniform(jax.random.key(1), (n, 8, 29, 29, 1))
        labels = jax.random.randint(jax.random.key(2), (n, 8), 0, 10)
        batch = {"images": imgs, "labels": labels}
        lr = 0.05

        fn = worker_train_fn(ops.loss, lambda s: lr, SyncConfig("bsp"), mesh)
        state = {"params": replicate_for_workers(params, n),
                 "step": jnp.zeros((n,), jnp.int32)}
        state, metrics = fn(state, batch)

        # serial reference: SGD on the concatenated batch
        flat = {"images": imgs.reshape(-1, 29, 29, 1), "labels": labels.reshape(-1)}
        g = jax.grad(lambda p, b: ops.loss(p, b)[0])(params, flat)
        ref = jax.tree.map(lambda p, gg: p - lr * gg, params, g)

        a = np.asarray(jax.tree.leaves(state["params"])[0][0])
        b = np.asarray(jax.tree.leaves(ref)[0])
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
        # all workers identical under bsp
        w = np.asarray(jax.tree.leaves(state["params"])[0])
        for i in range(1, n):
            np.testing.assert_allclose(w[0], w[i], atol=0)
        print("OK")
    """)
    assert "OK" in out


def test_worker_model_chaos_parity_and_staleness():
    """CHAOS workers: (a) stay deterministic, (b) converge to the same loss
    region as bsp (paper Result 4 analogue), (c) first step applies only the
    local gradient (remote contributions are one step stale)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.chaos import (SyncConfig, make_worker_step,
                                      worker_train_fn, replicate_for_workers,
                                      zeros_like_f32)
        from repro.launch.mesh import make_host_mesh
        import repro.configs as C
        from repro.models.api import get_ops
        from repro.data.mnist import make_dataset

        cfg = C.get("chaos-small")
        ops = get_ops(cfg)
        n = 4
        mesh = make_host_mesh(n)
        imgs, labels = make_dataset(n * 16 * 52, seed=0)
        lr = 0.05

        def run(mode, steps=50):
            params = ops.init(jax.random.key(0))
            state = {"params": replicate_for_workers(params, n),
                     "step": jnp.zeros((n,), jnp.int32)}
            if mode == "chaos":
                state["prev_grad"] = replicate_for_workers(
                    zeros_like_f32(params), n)
            fn = worker_train_fn(ops.loss, lambda s: lr, SyncConfig(mode), mesh)
            losses = []
            for t in range(steps):
                lo = t * n * 16
                b = {"images": imgs[lo:lo + n*16].reshape(n, 16, 29, 29, 1),
                     "labels": labels[lo:lo + n*16].reshape(n, 16)}
                state, m = fn(state, b)
                losses.append(float(m["loss"]))
            return losses

        l_bsp = run("bsp")
        l_chaos = run("chaos")
        l_local = run("localsgd")
        assert l_bsp[-1] < l_bsp[0] * 0.85, ("bsp no convergence", l_bsp)
        assert l_chaos[-1] < l_chaos[0] * 0.9, ("chaos no convergence", l_chaos)
        assert l_local[-1] < l_local[0] * 0.9, ("localsgd", l_local)
        # Result 4 analogue: final losses comparable (within 25%)
        assert abs(l_chaos[-1] - l_bsp[-1]) / l_bsp[-1] < 0.25, (l_chaos[-1], l_bsp[-1])
        print("OK", l_bsp[-1], l_chaos[-1], l_local[-1])
    """)
    assert "OK" in out


def test_localsgd_divergence_and_averaging():
    """Between syncs, localsgd workers diverge; at the K-step boundary all
    workers hold identical (averaged) params."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.chaos import SyncConfig, worker_train_fn, replicate_for_workers
        from repro.launch.mesh import make_host_mesh
        import repro.configs as C
        from repro.models.api import get_ops

        cfg = C.get("chaos-small")
        ops = get_ops(cfg)
        n = 4
        mesh = make_host_mesh(n)
        fn = worker_train_fn(ops.loss, lambda s: 0.05,
                             SyncConfig("localsgd", local_steps=4), mesh)
        params = ops.init(jax.random.key(0))
        state = {"params": replicate_for_workers(params, n),
                 "step": jnp.zeros((n,), jnp.int32)}
        for t in range(4):
            imgs = jax.random.uniform(jax.random.key(10 + t), (n, 8, 29, 29, 1))
            labels = jax.random.randint(jax.random.key(20 + t), (n, 8), 0, 10)
            state, _ = fn(state, {"images": imgs, "labels": labels})
            w = np.asarray(jax.tree.leaves(state["params"])[0], np.float32)
            identical = np.allclose(w[0], w[1], atol=1e-7)
            if t < 3:
                assert not identical, f"step {t}: workers should differ"
            else:
                assert identical, "step 3 (K=4): workers must be averaged"
        print("OK")
    """)
    assert "OK" in out
