"""Serving-path correctness (DESIGN.md §9): KV-cache capacity validation,
batched-prefill ≡ decode-loop parity for all three served families,
exact dispatch accounting, scheduler determinism, and slot reuse.

Parity contract: both sides run COMPILED (jit) — eager per-op execution
fuses differently and is not the serving configuration.  The KV families
(dense GQA, MLA) and the stateful family (rwkv6, whose default prefill
scans single-token decode steps inside one dispatch, re-rounding the WKV
state through the cache dtype exactly like the loop) are all bit-exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models.api import get_ops

SERVED = ("qwen3-14b", "minicpm3-4b", "rwkv6-1.6b")


def _setup(arch, B, max_seq, seed=0):
    cfg = C.smoke(arch)
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(seed))
    cache = ops.init_cache(B, max_seq)
    return cfg, ops, params, cache


# ---------------------------------------------------------------------------
# capacity: writes past max_seq must fail loudly, not silently clamp
# ---------------------------------------------------------------------------
def test_kv_cache_overflow_raises():
    """Regression: dynamic_update_slice clamps out-of-range start indices,
    so a decode past max_seq used to silently overwrite the LAST cache
    position; it must raise with an actionable message instead."""
    cfg, ops, params, cache = _setup("qwen3-14b", B=1, max_seq=8)
    tokens = jnp.zeros((1, 1), jnp.int32)
    # positions 0..7 fill the cache; position 8 must raise, not clamp
    for t in range(8):
        _, cache = ops.decode(params, cache, tokens, t)
    with pytest.raises(ValueError, match="max_seq"):
        ops.decode(params, cache, tokens, 8)
    # batched prefill overflow: 4 tokens into 2 remaining positions
    cache2 = ops.init_cache(1, 8)
    with pytest.raises(ValueError, match="overflow"):
        ops.prefill(params, cache2, jnp.zeros((1, 4), jnp.int32),
                    jnp.array([4]), 6)
    # vector-cursor path validates the max over rows
    with pytest.raises(ValueError, match="overflow"):
        ops.decode(params, ops.init_cache(2, 8), jnp.zeros((2, 1), jnp.int32),
                   np.array([3, 8], np.int32))


def test_engine_rejects_unservable_request():
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine("qwen3-14b", slots=2, max_seq=16)
    bad = Request(rid=0, tokens=np.zeros(12, np.int32), max_new=8)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(bad)


# ---------------------------------------------------------------------------
# batched prefill ≡ token-at-a-time decode loop (bit-exact, compiled)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", SERVED)
def test_prefill_matches_decode_loop(arch):
    B, T, max_seq = 2, 8, 32
    cfg, ops, params, cache = _setup(arch, B, max_seq)
    tokens = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)

    dec = jax.jit(lambda p, c, t, cl: ops.decode(p, c, t, cl))
    for t in range(T):
        logits_loop, cache = dec(params, cache, tokens[:, t:t + 1],
                                 jnp.int32(t))

    pre = jax.jit(lambda p, c, t, ln: ops.prefill(p, c, t, ln, 0))
    cache2 = ops.init_cache(B, max_seq)
    lens = jnp.full((B,), T, jnp.int32)
    logits_pre, cache2 = pre(params, cache2, tokens, lens)

    for k in cache:
        np.testing.assert_array_equal(np.asarray(cache[k]),
                                      np.asarray(cache2[k]))
    np.testing.assert_array_equal(np.asarray(logits_loop[:, 0]),
                                  np.asarray(logits_pre[:, T - 1]))


@pytest.mark.parametrize("arch", SERVED)
def test_ragged_prefill_matches_per_row_loop(arch):
    """Right-padded rows of different lengths: each row's cache and
    next-token logits must be bit-equal to decoding that row alone."""
    B, max_seq = 2, 32
    lens = [5, 8]
    cfg, ops, params, _ = _setup(arch, B, max_seq)
    tokens = jax.random.randint(jax.random.key(2), (B, max(lens)), 0,
                                cfg.vocab_size)
    tokens = tokens * (jnp.arange(max(lens))[None] < jnp.array(lens)[:, None])

    pre = jax.jit(lambda p, c, t, ln: ops.prefill(p, c, t, ln, 0))
    cache_b = ops.init_cache(B, max_seq)
    logits_b, cache_b = pre(params, cache_b, tokens,
                            jnp.array(lens, jnp.int32))

    dec = jax.jit(lambda p, c, t, cl: ops.decode(p, c, t, cl))
    for i, ln in enumerate(lens):
        row_cache = ops.init_cache(1, max_seq)
        for t in range(ln):
            logits_row, row_cache = dec(params, row_cache,
                                        tokens[i:i + 1, t:t + 1],
                                        jnp.int32(t))
        for k in row_cache:
            got = np.asarray(cache_b[k][:, i])
            want = np.asarray(row_cache[k][:, 0])
            if "wkv" not in row_cache:
                # KV rows: positions past the row's length hold bucket junk
                # that decode can never attend; compare the live prefix
                got, want = got[:, :ln], want[:, :ln]
            np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(np.asarray(logits_row[0, 0]),
                                      np.asarray(logits_b[i, ln - 1]))


def test_prefill_kernel_path_matches_jnp():
    """The Pallas q_offset kernel path (use_kernel=True) agrees with the
    jnp flash prefill on next-token logits."""
    B, T, max_seq = 2, 8, 32
    cfg, ops, params, _ = _setup("qwen3-14b", B, max_seq)
    tokens = jax.random.randint(jax.random.key(3), (B, T), 0, cfg.vocab_size)
    lens = jnp.full((B,), T, jnp.int32)
    c1, c2 = ops.init_cache(B, max_seq), ops.init_cache(B, max_seq)
    l_jnp, c1 = ops.prefill(params, c1, tokens, lens, 0)
    l_ker, c2 = ops.prefill(params, c2, tokens, lens, 0, use_kernel=True)
    np.testing.assert_allclose(
        np.asarray(l_jnp[:, -1], np.float32),
        np.asarray(l_ker[:, -1], np.float32), atol=2e-2, rtol=2e-2)
    # deeper layers' K/V depend on earlier layers' attention output, so the
    # two paths' caches agree to bf16 rounding, not bitwise
    for k in c1:
        np.testing.assert_allclose(np.asarray(c1[k], np.float32),
                                   np.asarray(c2[k], np.float32),
                                   atol=0.25, rtol=0.1)


# ---------------------------------------------------------------------------
# scheduler: dispatch accounting, determinism, slot reuse
# ---------------------------------------------------------------------------
def test_exact_dispatch_count():
    """A static batch generating ``gen`` tokens costs exactly 1 batched
    prefill + (gen-1) decode dispatches — no trailing wasted decode (the
    old loop ran one extra step whose logits were discarded), and sampling
    is fused on-device (no extra per-token dispatch)."""
    from repro.serve.engine import Request, ServeEngine
    gen = 6
    eng = ServeEngine("qwen3-14b", slots=2, max_seq=32)
    rng = np.random.default_rng(0)
    trace = [Request(rid=i, tokens=rng.integers(
        0, eng.cfg.vocab_size, size=(8,)).astype(np.int32), max_new=gen)
        for i in range(2)]
    finished = eng.run(trace)
    assert eng.counters["prefill_dispatch"] == 1
    assert eng.counters["decode_dispatch"] == gen - 1
    assert all(len(f.tokens) == gen for f in finished)


@pytest.mark.parametrize("arch", ["qwen3-14b", "rwkv6-1.6b"])
def test_scheduler_determinism(arch):
    """Same (seed, trace) => identical generated tokens regardless of slot
    count / admission interleaving — per-row computation is independent of
    batch neighbours and greedy sampling carries no RNG."""
    from repro.serve.engine import ServeEngine, poisson_trace
    cfg = C.smoke(arch)
    trace = poisson_trace(3, 6, 1.0, cfg.vocab_size, prompt_lens=(4, 10),
                          max_new=4)
    outs = {}
    for slots in (2, 4):
        eng = ServeEngine(arch, slots=slots, max_seq=32)
        fin = eng.run([r.__class__(**vars(r)) for r in trace])
        outs[slots] = {f.rid: f.tokens.tolist() for f in fin}
    assert outs[2] == outs[4]


def test_slot_reuse_and_free_map():
    """More requests than slots: eviction must recycle slots (free map
    returns to full), every request finishes, and admission is
    lowest-slot-first deterministic."""
    from repro.serve.engine import Request, ServeEngine
    eng = ServeEngine("rwkv6-1.6b", slots=2, max_seq=32)
    rng = np.random.default_rng(1)
    trace = [Request(rid=i, tokens=rng.integers(
        0, eng.cfg.vocab_size, size=(4 + i,)).astype(np.int32),
        max_new=3, arrival=0.0) for i in range(5)]
    finished = eng.run(trace)
    assert sorted(f.rid for f in finished) == list(range(5))
    assert eng.kv.free_count() == 2
    assert not eng.active and not eng.pending
    assert (eng.kv.cursors == 0).all()
    # prefill happened in >1 wave (2 slots, 5 requests)
    assert eng.counters["prefill_dispatch"] >= 3


def test_sampled_decode_determinism():
    """temperature > 0: seeded top-p sampling fused into the decode
    dispatch keeps the scheduler determinism contract — same (seed, trace)
    => identical tokens at any slot count, different seed => different
    tokens, greedy stays the default and is unaffected."""
    from repro.serve.engine import ServeEngine, poisson_trace
    cfg = C.smoke("qwen3-14b")
    trace = poisson_trace(5, 6, 1.0, cfg.vocab_size, prompt_lens=(4, 10),
                         max_new=4)

    def run(slots, **kw):
        eng = ServeEngine("qwen3-14b", slots=slots, max_seq=32, **kw)
        fin = eng.run([r.__class__(**vars(r)) for r in trace])
        return {f.rid: f.tokens.tolist() for f in fin}

    greedy = run(4)
    kw = dict(temperature=0.8, top_p=0.9, sample_seed=11)
    sampled = run(4, **kw)
    assert sampled != greedy                   # sampling actually samples
    assert run(2, **kw) == sampled             # slot-count invariant
    assert run(4, temperature=0.8, top_p=0.9, sample_seed=12) != sampled
    # loop-mode reference prefill samples the same first tokens
    assert run(4, prefill_mode="loop", **kw) == sampled
