"""SyncStrategy engine semantics (DESIGN.md §5).

The contracts:
  * the registry is the ONLY mode dispatch — step builders and the driver
    are strategy-agnostic, unknown modes fail with a clear error;
  * chaos(τ=0) RESOLVES to the bsp strategy object, so it is bit-exact to
    bsp by construction — verified end-to-end anyway (single path, Pallas
    kernel path, worker mesh, and driver die/resume across worker counts);
  * chaos(τ) generalises the staleness-1 exchange: the first τ steps apply
    the zero-initialised ring, and step τ+1's update equals bsp's step-1
    update on the same batch;
  * layerwise (per-layer non-instant updates during backprop) is bit-exact
    to the batched update for bsp+SGD on both the XLA and kernel paths,
    keeps chaos' staleness property, and composes with the superstep scan.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.chaos import SyncConfig
from repro.data.mnist import make_dataset
from repro.data.pipeline import ImagePipeline
from repro.optim import sgd
from repro.train.step import (init_train_state, make_optimizer,
                              make_superstep, make_train_step)
from repro.train.sync import (BspStrategy, ChaosStrategy, get_strategy,
                              sync_modes)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _states_bitexact(s1, s2, msg=""):
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32),
                                      err_msg=msg)


def _cnn(use_kernel=False):
    import dataclasses
    cfg = C.get("chaos-small")
    if use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=True)
    imgs, labels = make_dataset(64, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")
    return cfg, pipe


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_contents_and_unknown_mode():
    assert sync_modes() == ["bsp", "chaos", "localsgd"]
    with pytest.raises(ValueError, match="registered strategies"):
        get_strategy(SyncConfig(mode="definitely-not-a-mode"))


def test_chaos_tau0_resolves_to_bsp_object():
    strat = get_strategy(SyncConfig("chaos", staleness=0))
    assert type(strat) is BspStrategy  # not a subclass: THE bsp strategy
    assert strat.init_state({"w": jnp.zeros((2,))}) == {}
    assert not strat.stacked_state
    tau1 = get_strategy(SyncConfig("chaos", staleness=1))
    assert type(tau1) is ChaosStrategy
    assert tau1.stacked_state


def test_negative_staleness_rejected():
    with pytest.raises(ValueError, match="staleness"):
        SyncConfig("chaos", staleness=-1)


def test_step_builders_have_no_mode_branches():
    """Acceptance criterion: no per-mode dispatch outside the strategy
    modules — train/step.py and launch/train.py must not branch on the
    sync mode name."""
    import re
    for rel in ("src/repro/train/step.py", "src/repro/launch/train.py"):
        path = os.path.join(os.path.dirname(__file__), "..", rel)
        with open(path) as f:
            src = f.read()
        hits = re.findall(r"""mode\s*==\s*['"](bsp|chaos|localsgd)['"]""",
                          src)
        assert not hits, f"{rel} still branches on sync mode: {hits}"


# ---------------------------------------------------------------------------
# chaos(τ=0) ≡ bsp, single-instance path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_chaos_tau0_bitexact_vs_bsp_single_path(use_kernel):
    cfg, pipe = _cnn(use_kernel)
    states = {}
    for sync in (SyncConfig("bsp"), SyncConfig("chaos", staleness=0)):
        opt = make_optimizer(cfg, total_steps=8)
        fn = jax.jit(make_superstep(cfg, sync, opt))
        s = init_train_state(cfg, jax.random.key(0), sync, opt)
        s, m = fn(s, pipe.superstep_at(0, 3))
        states[sync.mode] = (s, np.asarray(m["loss"]))
    _states_bitexact(states["bsp"][0], states["chaos"][0],
                     f"tau=0 vs bsp kernel={use_kernel}")
    np.testing.assert_array_equal(states["bsp"][1], states["chaos"][1])


def test_chaos_tau_staleness_property_single_path():
    """τ=2 with a constant-lr SGD on one repeated batch: steps 1..τ are
    no-ops (zero-initialised ring) and step τ+1's update equals bsp's
    step-1 update — the τ-generalisation of the staleness-1 rule."""
    cfg, pipe = _cnn()
    opt = sgd(lambda s: 0.05)
    batch = pipe.batch_at(0)
    sync_c = SyncConfig("chaos", staleness=2)
    step_c = jax.jit(make_train_step(cfg, sync_c, opt))
    step_b = jax.jit(make_train_step(cfg, SyncConfig("bsp"), opt))
    s_c = init_train_state(cfg, jax.random.key(0), sync_c, opt)
    s_b = init_train_state(cfg, jax.random.key(0), SyncConfig("bsp"), opt)
    p0 = jax.tree.map(np.asarray, s_c["params"])

    s_c, _ = step_c(s_c, batch)
    _states_bitexact(p0, s_c["params"], "step 1 must be a no-op")
    s_c, _ = step_c(s_c, batch)
    _states_bitexact(p0, s_c["params"], "step 2 must be a no-op (tau=2)")
    s_c, _ = step_c(s_c, batch)
    s_b, _ = step_b(s_b, batch)
    # cross-program comparison (chaos's gradient feeds the ring selects,
    # bsp's feeds the optimizer, so XLA fuses the two programs differently
    # at the 1-ulp level) — same tolerance as test_chaos.py's staleness-1
    # version of this property
    for a, b in zip(jax.tree.leaves(s_c["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-6,
            err_msg="step 3 == bsp step 1 (same batch, 2-step-stale grad)")


@pytest.mark.parametrize("tau", [2, 4])
def test_superstep_bitexact_vs_individual_dispatches_tau(tau):
    """The τ-deep ring buffer rides the scan carry: K=4 scanned is
    bit-identical to 4 single-step dispatches for any τ."""
    cfg, pipe = _cnn()
    sync = SyncConfig("chaos", staleness=tau)
    opt = make_optimizer(cfg, total_steps=8)
    fn = jax.jit(make_superstep(cfg, sync, opt))
    s1 = init_train_state(cfg, jax.random.key(0), sync, opt)
    s2 = init_train_state(cfg, jax.random.key(0), sync, opt)
    for t in range(4):
        s1, _ = fn(s1, pipe.superstep_at(t, 1))
    s2, _ = fn(s2, pipe.superstep_at(0, 4))
    _states_bitexact(s1, s2, f"tau={tau} scan vs individual")


def test_chaos_ring_state_shape_and_specs():
    """The τ-deep ring is τ params-shaped slot trees (h0..h{τ-1}) in param
    dtype, each sharded exactly like params."""
    cfg, _ = _cnn()
    sync = SyncConfig("chaos", staleness=3)
    opt = make_optimizer(cfg, total_steps=8)
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    assert sorted(state["sync"]["hist"]) == ["h0", "h1", "h2"]
    for slot in state["sync"]["hist"].values():
        for p, h in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(slot)):
            assert h.shape == p.shape and h.dtype == p.dtype
    from repro.train.step import state_specs
    specs = state_specs(cfg, sync, opt)
    assert sorted(specs["sync"]["hist"]) == ["h0", "h1", "h2"]
    for slot_spec in specs["sync"]["hist"].values():
        assert jax.tree.structure(
            slot_spec, is_leaf=lambda x: x is None) is not None


# ---------------------------------------------------------------------------
# worker mesh: τ=0 ≡ bsp bit-exact, τ>=1 stacked + diverging
# ---------------------------------------------------------------------------
def _run_sub(code: str, n_dev: int = 8):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


_WORKER_SETUP = """
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.core.types import WorkerConfig
    from repro.data.mnist import make_dataset
    from repro.data.pipeline import ImagePipeline
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import put_worker_sharded
    from repro.train.step import (init_worker_state, make_optimizer,
                                  make_worker_superstep)

    cfg = C.get("chaos-small")
    imgs, labels = make_dataset(128, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")

    def run(n, mode, tau=1, steps=4, K=2, cfg=cfg, layerwise=False,
            compress=False, optim="auto"):
        worker = WorkerConfig(workers=n)
        mesh = make_host_mesh(n)
        sync = SyncConfig(mode, staleness=tau, axis_name=worker.axis,
                          layerwise=layerwise, compress=compress)
        opt = make_optimizer(cfg, total_steps=64, kind=optim)
        fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
        state = init_worker_state(cfg, jax.random.key(0), sync, worker, opt)
        losses = []
        for s in range(0, steps, K):
            state, m = fn(state, put_worker_sharded(pipe, s, K, mesh,
                                                    worker))
            losses.extend(np.asarray(m["loss"]).tolist())
        return jax.tree.map(np.asarray, state), losses

    def assert_tree_equal(a, b, msg=""):
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=msg)
"""


def test_chaos_tau0_bitexact_vs_bsp_worker_mesh():
    """chaos τ=0 on the worker mesh: full TrainState AND the logged (K,)
    loss vectors bit-exact vs bsp at N=1/2/4 — and worker-count-invariant
    like bsp (the acceptance criterion)."""
    out = _run_sub(_WORKER_SETUP + """
    s_b4, l_b4 = run(4, "bsp")
    for n in (1, 2, 4):
        s_c, l_c = run(n, "chaos", tau=0)
        assert_tree_equal(s_b4, s_c, f"chaos tau=0 N={n} vs bsp N=4")
        np.testing.assert_array_equal(np.asarray(l_b4), np.asarray(l_c))
    print("OK")
    """)
    assert "OK" in out


def test_chaos_tau0_bitexact_kernel_path_worker_mesh():
    out = _run_sub(_WORKER_SETUP + """
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    s_b, l_b = run(2, "bsp", steps=2, cfg=kcfg)
    s_c, l_c = run(2, "chaos", tau=0, steps=2, cfg=kcfg)
    assert np.all(np.isfinite(np.asarray(l_b)))
    assert_tree_equal(s_b, s_c, "kernel path chaos tau=0 vs bsp")
    np.testing.assert_array_equal(np.asarray(l_b), np.asarray(l_c))
    print("OK")
    """)
    assert "OK" in out


def test_chaos_tau_worker_state_stacked_and_diverging():
    """τ>=1 workers hold their own weights (controlled Hogwild): state is
    (N, ...)-stacked, workers diverge, and at N=1 (no peers — every shard
    is local) the updates match bsp exactly."""
    out = _run_sub(_WORKER_SETUP + """
    s_c, _ = run(4, "chaos", tau=2, steps=3, K=1)
    leaf = jax.tree.leaves(s_c["params"])[0]
    assert leaf.shape[0] == 4, "tau>=1 worker state must be stacked"
    assert not np.allclose(leaf[0], leaf[1]), "workers must diverge"
    # hist ring is per worker too: tau slot trees, each (N, ...)-stacked
    assert sorted(s_c["sync"]["hist"]) == ["h0", "h1"]
    h = jax.tree.leaves(s_c["sync"]["hist"]["h0"])[0]
    assert h.shape[0] == 4, h.shape

    s_1, l_1 = run(1, "chaos", tau=2)
    s_b, l_b = run(1, "bsp")
    for a, b in zip(jax.tree.leaves(s_1["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))
    np.testing.assert_array_equal(np.asarray(l_1), np.asarray(l_b))
    print("OK")
    """)
    assert "OK" in out


def _run_driver(args, ckpt_dir, n_dev=8, die_at=None):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "chaos-small", "--steps", "8", "--superstep", "4",
           "--ckpt-every", "4", "--ckpt-dir", ckpt_dir] + args
    if die_at is not None:
        cmd += ["--die-at-step", str(die_at)]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=900)


def test_driver_die_resume_chaos_tau0_across_worker_counts(tmp_path):
    """Acceptance criterion: chaos τ=0 through the driver — die at a
    superstep boundary under N=4, resume under N=2, and the final
    checkpoint is bit-identical to an uninterrupted N=4 run's (τ=0
    checkpoints are worker-count-invariant, exactly like bsp)."""
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    args = ["--workers", "4", "--sync", "chaos", "--staleness", "0"]

    first = _run_driver(args, a, die_at=4)
    assert first.returncode == 17, first.stderr[-2000:]
    second = _run_driver(["--workers", "2", "--sync", "chaos",
                          "--staleness", "0"], a)
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed from step 4" in second.stdout
    straight = _run_driver(args, b)
    assert straight.returncode == 0, straight.stderr[-2000:]

    fa = np.load(os.path.join(a, "step_0000000008", "arrays.npz"))
    fb = np.load(os.path.join(b, "step_0000000008", "arrays.npz"))
    assert fa.files == fb.files
    for k in fa.files:
        np.testing.assert_array_equal(fa[k], fb[k])


def test_driver_chaos_tau_checkpoint_pins_worker_count(tmp_path):
    """τ>=1 worker state genuinely diverges, so its stacked checkpoint must
    refuse a different worker count — and the error names the offending
    leaf path with both shapes (satellite bugfix)."""
    d = str(tmp_path / "tau2")
    first = _run_driver(["--workers", "4", "--sync", "chaos",
                         "--staleness", "2"], d, die_at=4)
    assert first.returncode == 17, first.stderr[-2000:]
    bad = _run_driver(["--workers", "2", "--sync", "chaos",
                       "--staleness", "2"], d)
    assert bad.returncode != 0
    assert "different state layout" in bad.stderr
    assert "['params']" in bad.stderr  # leaf path named


# ---------------------------------------------------------------------------
# layerwise: per-layer non-instant updates during backprop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True])
def test_layerwise_bsp_bitexact_vs_batched_update(use_kernel):
    """Applying dW_l the moment layer l's gradient is produced (reverse
    layer order, chained in the graph) computes bit-identically to the
    whole-tree update for SGD — on both the XLA and Pallas-kernel paths."""
    cfg, pipe = _cnn(use_kernel)
    opt = make_optimizer(cfg, total_steps=8)
    s_ref = init_train_state(cfg, jax.random.key(0), SyncConfig("bsp"), opt)
    s_lw = init_train_state(cfg, jax.random.key(0),
                            SyncConfig("bsp", layerwise=True), opt)
    ref = jax.jit(make_superstep(cfg, SyncConfig("bsp"), opt))
    lw = jax.jit(make_superstep(cfg, SyncConfig("bsp", layerwise=True),
                                opt))
    k = 2 if use_kernel else 4
    s_ref, m_ref = ref(s_ref, pipe.superstep_at(0, k))
    s_lw, m_lw = lw(s_lw, pipe.superstep_at(0, k))
    _states_bitexact(s_ref["params"], s_lw["params"],
                     f"layerwise kernel={use_kernel}")
    np.testing.assert_array_equal(np.asarray(m_ref["loss"]),
                                  np.asarray(m_lw["loss"]))


def test_layerwise_chaos_staleness_property():
    """Layerwise chaos τ=1 (the paper's ordering: forward at pre-update
    weights, per-layer stale updates during backprop): step 1 is a no-op
    and step 2's update equals bsp's step-1 update on the same batch."""
    cfg, pipe = _cnn()
    opt = sgd(lambda s: 0.05)
    batch = pipe.batch_at(0)
    sync = SyncConfig("chaos", staleness=1, layerwise=True)
    step_c = jax.jit(make_train_step(cfg, sync, opt))
    step_b = jax.jit(make_train_step(cfg, SyncConfig("bsp"), opt))
    s_c = init_train_state(cfg, jax.random.key(0), sync, opt)
    s_b = init_train_state(cfg, jax.random.key(0), SyncConfig("bsp"), opt)
    p0 = jax.tree.map(np.asarray, s_c["params"])
    s_c, _ = step_c(s_c, batch)
    _states_bitexact(p0, s_c["params"], "layerwise chaos step 1 no-op")
    s_c, _ = step_c(s_c, batch)
    s_b, _ = step_b(s_b, batch)
    _states_bitexact(s_c["params"], s_b["params"],
                     "layerwise chaos step 2 == bsp step 1")


def test_layerwise_localsgd_single_replica_matches_bsp():
    """localsgd's boundary hook composes with the layerwise walk; on a
    single replica the average is the identity, so it matches bsp."""
    cfg, pipe = _cnn()
    opt = make_optimizer(cfg, total_steps=8)
    lw_b = jax.jit(make_superstep(cfg, SyncConfig("bsp", layerwise=True),
                                  opt))
    lw_l = jax.jit(make_superstep(
        cfg, SyncConfig("localsgd", local_steps=2, layerwise=True), opt))
    s_b = init_train_state(cfg, jax.random.key(0),
                           SyncConfig("bsp", layerwise=True), opt)
    s_l = init_train_state(
        cfg, jax.random.key(0),
        SyncConfig("localsgd", local_steps=2, layerwise=True), opt)
    s_b, _ = lw_b(s_b, pipe.superstep_at(0, 4))
    s_l, _ = lw_l(s_l, pipe.superstep_at(0, 4))
    _states_bitexact(s_b["params"], s_l["params"])


def test_layerwise_worker_mesh_bitexact_vs_batched():
    """Acceptance criterion: layerwise bsp+SGD on the worker mesh — every
    bucket runs its own gathered_shard_mean — is bit-exact to the batched
    (one stacked reduction) update at N ∈ {1, 2, 4}, losses included."""
    out = _run_sub(_WORKER_SETUP + """
    s_ref, l_ref = run(4, "bsp")
    for n in (1, 2, 4):
        s_lw, l_lw = run(n, "bsp", layerwise=True)
        assert_tree_equal(s_ref, s_lw, f"worker layerwise N={n}")
        np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_lw))
    print("OK")
    """)
    assert "OK" in out


def test_layerwise_worker_mesh_bitexact_kernel_path():
    """Same acceptance criterion through the fused Pallas kernel path."""
    out = _run_sub(_WORKER_SETUP + """
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    s_ref, l_ref = run(2, "bsp", steps=2, cfg=kcfg)
    for n in (1, 2, 4):
        s_lw, l_lw = run(n, "bsp", steps=2, cfg=kcfg, layerwise=True)
        assert_tree_equal(s_ref, s_lw, f"kernel worker layerwise N={n}")
        np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_lw))
    print("OK")
    """)
    assert "OK" in out


def test_layerwise_worker_mesh_adamw_and_chaos_run():
    """Stateful optimizers + chaos τ>=1 compose with worker-mesh layerwise:
    adamw trains finitely, chaos τ=1 at N=1 (no peers -> remote term 0)
    matches bsp exactly, and at N=4 the workers diverge (stacked state)."""
    out = _run_sub(_WORKER_SETUP + """
    s_a, l_a = run(2, "bsp", layerwise=True, optim="adamw")
    assert np.all(np.isfinite(np.asarray(l_a)))

    s_c, l_c = run(1, "chaos", tau=1, layerwise=True)
    s_b, l_b = run(1, "bsp", layerwise=True)
    for a, b in zip(jax.tree.leaves(s_c["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))

    s_c4, _ = run(4, "chaos", tau=1, layerwise=True, steps=3, K=1)
    leaf = jax.tree.leaves(s_c4["params"])[0]
    assert leaf.shape[0] == 4 and not np.allclose(leaf[0], leaf[1])
    print("OK")
    """)
    assert "OK" in out


def test_compress_worker_mesh_bitexact_across_worker_counts():
    """Acceptance criterion: SyncConfig.compress no longer raises on the
    worker mesh.  The bf16 exchange quantises per micro-shard with a
    SHARD-stacked (logical_shards, ...) error-feedback residual, so the
    full TrainState — residual included — is bit-exact for every worker
    count dividing logical_shards, for bsp AND hogwild chaos."""
    out = _run_sub(_WORKER_SETUP + """
    from repro.core.chaos import compress_grads
    s1, l1 = run(1, "bsp", compress=True)
    for n in (2, 4):
        sn, ln = run(n, "bsp", compress=True)
        assert_tree_equal(s1, sn, f"compress N=1 vs N={n}")
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(ln))
    res = jax.tree.leaves(s1["sync"]["residual"])[0]
    assert res.shape[0] == 8, res.shape  # logical_shards-stacked
    assert np.any(np.asarray(res) != 0)  # quantisation error carried

    # hogwild chaos + compress: at N=1 every shard is local, so the remote
    # term is exactly 0 and the compressed chaos trajectory == compressed
    # bsp (params AND the shard-stacked residual)
    c1, _ = run(1, "chaos", tau=1, compress=True, steps=3, K=1)
    b1, _ = run(1, "bsp", compress=True, steps=3, K=1)
    for a, b in zip(jax.tree.leaves(c1["params"]),
                    jax.tree.leaves(b1["params"])):
        np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))
    for a, b in zip(jax.tree.leaves(c1["sync"]["residual"]),
                    jax.tree.leaves(b1["sync"]["residual"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c4, _ = run(4, "chaos", tau=1, compress=True, steps=3, K=1)
    assert np.all(np.isfinite(np.asarray(
        jax.tree.leaves(c4["params"])[0])))
    print("OK")
    """)
    assert "OK" in out


def test_layerwise_guards_lifted():
    """The ParamBuckets redesign lifted the CNN-only / stateless-SGD-only /
    no-compression / no-worker-mesh layerwise guards, and the overlap PR
    lifted the last one — micro-batch accumulation.  Every combo now
    BUILDS, and the micro-batch combo trains (numerics pinned against the
    batched path in test_overlap.py)."""
    import dataclasses

    from repro.core.types import WorkerConfig
    from repro.optim import adamw
    from repro.train.step import make_worker_train_step

    lw = SyncConfig("bsp", layerwise=True)
    lm_cfg = C.smoke("qwen3-14b")
    make_train_step(lm_cfg, lw, make_optimizer(lm_cfg, total_steps=8))
    cfg, _ = _cnn()
    make_train_step(cfg, lw, adamw(lambda s: 1e-3))
    make_train_step(cfg, SyncConfig("bsp", layerwise=True, compress=True),
                    sgd(lambda s: 1e-3))
    make_worker_train_step(cfg, lw, WorkerConfig(workers=1))

    micro = dataclasses.replace(cfg, micro_batches=2)
    opt = sgd(lambda s: 1e-3)
    step_fn = jax.jit(make_train_step(micro, lw, opt))
    _, pipe = _cnn()
    state = init_train_state(micro, jax.random.key(0), lw, opt)
    state, metrics = step_fn(state, pipe.batch_at(0))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
