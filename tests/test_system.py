"""End-to-end behaviour tests: the paper's CNN training converges, CHAOS
matches BSP accuracy (Result 4 analogue at pjit level), the LM path learns,
and MoE routing invariants hold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.chaos import SyncConfig
from repro.data.mnist import splits
from repro.data.pipeline import ImagePipeline, TokenPipeline
from repro.models.api import get_ops
from repro.train.step import init_train_state, make_optimizer, make_train_step


def _train_cnn(sync_mode: str, steps: int = 110, lr=0.05, seed=0):
    cfg = C.get("chaos-small")
    sync = SyncConfig(mode=sync_mode)
    from repro.optim import sgd
    opt = sgd(lambda s: lr)
    step = jax.jit(make_train_step(cfg, sync, opt))
    state = init_train_state(cfg, jax.random.key(seed), sync, opt)
    (xi, yi), _, (xt, yt) = splits(1024, 64, 256, seed=0)
    pipe = ImagePipeline(xi, yi, batch=32)
    for t in range(steps):
        state, metrics = step(state, pipe.batch_at(t))
    ops = get_ops(cfg)
    test_loss, m = ops.loss(state["params"], {"images": xt, "labels": yt})
    return float(metrics["loss"]), float(m["error_rate"]), float(test_loss)


def test_cnn_training_converges_bsp():
    train_loss, err, _ = _train_cnn("bsp")
    assert train_loss < 1.3, train_loss
    assert err < 0.45, err  # way better than 0.9 chance


def test_chaos_accuracy_parity_with_bsp():
    """Paper Result 4: parallel (CHAOS) accuracy comparable to sequential."""
    _, err_bsp, loss_bsp = _train_cnn("bsp")
    _, err_chaos, loss_chaos = _train_cnn("chaos")
    assert abs(err_chaos - err_bsp) < 0.12, (err_bsp, err_chaos)
    assert loss_chaos < loss_bsp * 1.35 + 0.1


def test_lm_learns_bigram_structure():
    cfg = C.smoke("qwen3-14b")
    sync = SyncConfig("bsp")
    opt = make_optimizer(cfg, base_lr=3e-3, total_steps=80)
    step = jax.jit(make_train_step(cfg, sync, opt), donate_argnums=(0,))
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=64)
    losses = []
    for t in range(80):
        state, m = step(state, pipe.batch_at(t))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, (
        losses[:5], losses[-5:])


def test_minicpm_wsd_schedule_trains_stably():
    """minicpm (tied embeddings + WSD warmup) trains without NaN and
    improves — regression for the flash-backward masked-overflow bug."""
    cfg = C.smoke("minicpm-2b")
    sync = SyncConfig("bsp")
    opt = make_optimizer(cfg, base_lr=3e-3, total_steps=60)
    step = jax.jit(make_train_step(cfg, sync, opt), donate_argnums=(0,))
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=64)
    losses = []
    for t in range(60):
        state, m = step(state, pipe.batch_at(t))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), "NaN during minicpm training"
    # WSD warmup covers most of this short run -> modest but real progress
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.1


def test_microbatching_matches_full_batch():
    """Gradient accumulation must reproduce the full-batch gradient step."""
    import dataclasses
    cfg = C.smoke("qwen3-14b")
    from repro.optim import sgd
    opt = sgd(lambda s: 0.01)
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    s1 = init_train_state(cfg, jax.random.key(0), SyncConfig("bsp"), opt)
    step1 = jax.jit(make_train_step(cfg, SyncConfig("bsp"), opt))
    out1, m1 = step1(s1, batch)

    cfg2 = dataclasses.replace(cfg, micro_batches=2)
    s2 = init_train_state(cfg2, jax.random.key(0), SyncConfig("bsp"), opt)
    step2 = jax.jit(make_train_step(cfg2, SyncConfig("bsp"), opt))
    out2, m2 = step2(s2, batch)

    a = np.asarray(jax.tree.leaves(out1["params"])[0], np.float32)
    b = np.asarray(jax.tree.leaves(out2["params"])[0], np.float32)
    np.testing.assert_allclose(a, b, atol=5e-3, rtol=5e-3)


def test_moe_routing_invariants():
    """Top-k dispatch: output matches a dense weighted mixture of expert
    MLPs when capacity pressure is off; aux loss ~1 for balanced routing."""
    import dataclasses
    from repro.models.lm import moe_block, _moe_params
    from repro.models import layers as L

    cfg = dataclasses.replace(C.smoke("qwen3-moe-30b-a3b"),
                              capacity_factor=8.0)
    p = _moe_params(cfg, L.InitFactory(jax.random.key(0), jnp.float32))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.5  # ~1.0 for balanced routing

    # dense reference: weighted sum over top-k expert MLPs
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gw, gi = jax.lax.top_k(probs, cfg.top_k)
    gw = gw / gw.sum(-1, keepdims=True)

    def expert(e, v):
        h = jax.nn.silu(v @ p["w_gate"][e]) * (v @ p["w_up"][e])
        return h @ p["w_down"][e]

    ref = jnp.zeros_like(x)
    for b in range(2):
        for t in range(16):
            acc = sum(gw[b, t, j] * expert(int(gi[b, t, j]), x[b, t])
                      for j in range(cfg.top_k))
            ref = ref.at[b, t].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-3, rtol=2e-3)
