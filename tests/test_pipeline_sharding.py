"""Property tests for the pipelines' worker sharding (DESIGN.md §4).

The contract backing the worker-mesh route: for ANY (n_workers, K, batch,
step), concatenating the per-worker shards reconstructs the stacked
superstep batch exactly — N workers consume the SAME global sample
sequence as one worker (the paper's shared-queue semantics) — and in queue
mode one epoch's worth of worker shards covers every sample exactly once
(no example dropped or duplicated by the sharding)."""
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.data.pipeline import ImagePipeline, TokenPipeline

N_IMAGES = 64

# images tagged by dataset index so sample identity is exactly readable
IMAGES = (np.arange(N_IMAGES, dtype=np.float32).reshape(N_IMAGES, 1, 1, 1)
          * np.ones((1, 4, 4, 1), np.float32))
LABELS = (np.arange(N_IMAGES) % 10).astype(np.int32)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(1, 5),
       st.sampled_from([8, 16]), st.integers(0, 57), st.booleans())
def test_image_worker_shards_concat_to_superstep(n, k, b, step, queue):
    pipe = ImagePipeline(IMAGES, LABELS, batch=b,
                         sample_mode="queue" if queue else "iid")
    full = pipe.superstep_at(step, k)
    shards = [pipe.worker_superstep_at(step, k, n, w) for w in range(n)]
    for key in full:
        np.testing.assert_array_equal(
            np.concatenate([s[key] for s in shards], axis=1), full[key],
            err_msg=f"n={n} k={k} b={b} step={step} queue={queue} {key}")
    # equal shard sizes: no example dropped or duplicated within the batch
    for s in shards:
        assert s["images"].shape == (k, b // n, 4, 4, 1)


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(1, 4),
       st.sampled_from([8, 16]), st.integers(0, 97))
def test_token_worker_shards_concat_to_superstep(n, k, b, step):
    pipe = TokenPipeline(vocab_size=97, batch=b, seq_len=12)
    full = pipe.superstep_at(step, k)
    shards = [pipe.worker_superstep_at(step, k, n, w) for w in range(n)]
    for key in full:
        np.testing.assert_array_equal(
            np.concatenate([s[key] for s in shards], axis=1), full[key])


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 3))
def test_queue_mode_epoch_coverage_across_worker_shards(n, epoch):
    """Across one epoch, the union of every worker's shards is exactly the
    dataset: the shared queue hands each image to exactly one worker."""
    b = 8
    pipe = ImagePipeline(IMAGES, LABELS, batch=b, sample_mode="queue")
    steps_per_epoch = N_IMAGES // b
    seen = []
    for t in range(epoch * steps_per_epoch, (epoch + 1) * steps_per_epoch):
        for w in range(n):
            shard = pipe.worker_superstep_at(t, 1, n, w)
            seen.extend(shard["images"][0, :, 0, 0, 0].astype(int).tolist())
    assert sorted(seen) == list(range(N_IMAGES)), (
        f"epoch {epoch} with {n} workers must cover every sample once")


@settings(max_examples=25, deadline=None)
@given(st.sampled_from([3, 5, 7, 12, 24]), st.integers(0, 40))
def test_queue_mode_non_dividing_batch_is_epoch_stream(b, step):
    """Satellite: with a batch size that does NOT divide the dataset
    length, queue mode is the contiguous chunk [step*B, (step+1)*B) of the
    infinite stream of concatenated per-epoch permutations — batches
    straddle epoch boundaries instead of dropping the epoch tail or
    duplicating wrapped-around samples."""
    pipe = ImagePipeline(IMAGES, LABELS, batch=b, sample_mode="queue")
    got = pipe.batch_at(step)["images"][:, 0, 0, 0].astype(int)
    assert got.shape == (b,)
    # reference stream: concatenated epoch permutations (the pipeline's
    # documented seeding contract)
    lo, hi = step * b, (step + 1) * b
    perms = [np.random.default_rng(
        np.random.SeedSequence([pipe.seed, e])).permutation(N_IMAGES)
        for e in range(hi // N_IMAGES + 1)]
    stream = np.concatenate(perms)
    np.testing.assert_array_equal(got, stream[lo:hi])
    # determinism: pure function of step (no cache-order dependence)
    np.testing.assert_array_equal(
        got, pipe.batch_at(step)["images"][:, 0, 0, 0].astype(int))


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([5, 7, 12]), st.integers(0, 2))
def test_queue_mode_non_dividing_batch_epoch_coverage(b, epoch):
    """Every window of N_IMAGES consecutive stream samples that aligns with
    an epoch boundary covers the dataset exactly once — no sample is
    dropped or duplicated by a non-dividing batch size."""
    pipe = ImagePipeline(IMAGES, LABELS, batch=b, sample_mode="queue")
    lo, hi = epoch * N_IMAGES, (epoch + 1) * N_IMAGES
    seen = []
    for t in range(lo // b, hi // b + 1):
        ids = pipe.batch_at(t)["images"][:, 0, 0, 0].astype(int).tolist()
        for j, g in enumerate(range(t * b, (t + 1) * b)):
            if lo <= g < hi:
                seen.append(ids[j])
    assert sorted(seen) == list(range(N_IMAGES)), (
        f"epoch {epoch} with batch {b} must cover every sample once")


def test_worker_shard_validation():
    pipe = ImagePipeline(IMAGES, LABELS, batch=8, sample_mode="queue")
    with pytest.raises(ValueError, match="divisible by n_workers"):
        pipe.worker_superstep_at(0, 1, 3, 0)  # 8 % 3 != 0
    with pytest.raises(ValueError, match="out of range"):
        pipe.worker_superstep_at(0, 1, 4, 4)
    with pytest.raises(ValueError, match="out of range"):
        pipe.worker_superstep_at(0, 1, 4, -1)
