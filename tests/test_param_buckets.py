"""ParamBuckets API contracts (DESIGN.md §6).

* ``bucket_spec()`` is an exact disjoint ordered cover of the param tree
  for EVERY registered model family (hypothesis property over families ×
  construction seeds — the spec must hold for any config the family
  builds).
* Bucket-tape gradients concatenate bit-exactly to ``loss_and_grads``:
  the reverse-production walk yields every bucket exactly once, and
  reassembling the per-bucket gradients reproduces the whole-tree gradient
  bit-for-bit (CNN true VJP tape on both the XLA and Pallas-kernel paths;
  generic walk for the token families).
* Optimizer ``slice_state``/``merge_state`` round-trip: slicing every
  bucket and merging back reproduces the state tree exactly.
* Per-bucket compression: the layerwise error-feedback residual round-trips
  bit-exactly against whole-tree ``compress_grads``.
* ``SyncConfig.ring_dtype``: bf16 ring slots halve ring bytes; the first τ
  steps stay exact no-ops (zeros are bf16-exact).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.chaos import SyncConfig, compress_grads
from repro.models.api import get_ops, validate_bucket_spec
from repro.optim import adamw, sgd
from repro.train.step import init_train_state, make_optimizer, make_train_step
from tests._hypothesis_compat import given, settings, strategies as st

#: one representative arch per registered model family
FAMILY_ARCHS = {
    "dense": "qwen3-14b",
    "mla": "minicpm3-4b",
    "moe": "qwen3-moe-30b-a3b",
    "vlm": "llava-next-34b",
    "hybrid": "zamba2-1.2b",
    "ssm": "rwkv6-1.6b",
    "encdec": "whisper-small",
    "cnn": "chaos-small",
}


def _batch(cfg, key, B=2, T=16):
    if cfg.family == "cnn":
        imgs = jax.random.uniform(key, (B, 29, 29, 1))
        labels = jax.random.randint(key, (B,), 0, cfg.n_classes)
        return {"images": imgs, "labels": labels}
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return batch


# ---------------------------------------------------------------------------
# exact disjoint cover, every family
# ---------------------------------------------------------------------------
@settings(max_examples=16, deadline=None)
@given(family=st.sampled_from(sorted(FAMILY_ARCHS)),
       tie=st.booleans())
def test_bucket_spec_exact_disjoint_cover(family, tie):
    cfg = C.smoke(FAMILY_ARCHS[family])
    if cfg.family != "cnn":
        cfg = dataclasses.replace(cfg, tie_embeddings=tie)
    ops = get_ops(cfg)
    spec = ops.bucket_spec()
    abstract = ops.abstract_params()
    validate_bucket_spec(spec, abstract)  # raises on overlap/miss/disorder
    covered = [k for b in spec for k in b.keys]
    assert sorted(covered) == sorted(abstract)
    assert len(set(covered)) == len(covered)
    # views reassemble the tree exactly
    merged = {}
    for b in spec:
        merged.update(b.view(abstract))
    assert jax.tree.structure(dict(merged)) == jax.tree.structure(
        dict(abstract))


@pytest.mark.parametrize("chunk,tie", [(0, True), (1, True), (2, False)])
def test_chunked_lm_bucket_spec_exact_disjoint_cover(chunk, tie):
    """DESIGN.md §10: ``layer_chunk`` splits the scan stack into per-chunk
    buckets — the cover must stay exact and keep production order
    (embed -> layers0..M-1 -> final_norm [-> out_embed])."""
    cfg = dataclasses.replace(C.get("lm-bench"), layer_chunk=chunk,
                              tie_embeddings=tie)
    ops = get_ops(cfg)
    spec = ops.bucket_spec()
    abstract = ops.abstract_params()
    validate_bucket_spec(spec, abstract)
    covered = [k for b in spec for k in b.keys]
    assert sorted(covered) == sorted(abstract)
    names = [b.name for b in spec]
    from repro.models.lm import chunk_keys
    want = ["embed"] + list(chunk_keys(cfg)) + ["final_norm"]
    if not tie:
        want.append("out_embed")
    assert names == want


def test_validate_bucket_spec_rejects_bad_specs():
    from repro.core.types import ParamBucket
    abstract = {"a": 0, "b": 0}
    with pytest.raises(ValueError, match="misses"):
        validate_bucket_spec((ParamBucket("a", ("a",), 0),), abstract)
    with pytest.raises(ValueError, match="overlaps"):
        validate_bucket_spec((ParamBucket("a", ("a",), 0),
                              ParamBucket("x", ("a", "b"), 1)), abstract)
    with pytest.raises(ValueError, match="unknown"):
        validate_bucket_spec((ParamBucket("a", ("a", "z"), 0),), abstract)


# ---------------------------------------------------------------------------
# bucket tape == whole-tree gradients, bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,use_kernel", [
    ("chaos-small", False), ("chaos-small", True), ("qwen3-14b", False),
    ("whisper-small", False)])
def test_bucket_tape_concatenates_bitexact_to_loss_and_grads(arch,
                                                             use_kernel):
    cfg = C.smoke(arch)
    if use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=True)
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    spec = ops.bucket_spec()

    loss_w, metrics_w, grads_w = jax.jit(ops.loss_and_grads)(params, batch)

    # visit order + coverage: the tape yields every bucket exactly once in
    # reverse-production order, and returning None leaves params untouched
    seen = []
    _, _, new_params, _ = ops.loss_and_grads(
        params, batch, tape=lambda b, p, g: seen.append((b.name, g)))
    assert [n for n, _ in seen] == [b.name for b in reversed(spec)]
    concat = {}
    for _, g_b in seen:
        concat.update(g_b)
    assert sorted(concat) == sorted(grads_w)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    # bit-exactness: the tape-mode grads (assembled from the per-bucket
    # walk) equal the whole-tree grads, comparing like with like (both
    # jitted — jit-vs-eager fusion differs at 1 ulp on the kernel path)
    @jax.jit
    def taped(params, batch):
        return ops.loss_and_grads(params, batch,
                                  tape=lambda b, p, g: None)

    loss_t, _, _, grads_t = taped(params, batch)
    np.testing.assert_array_equal(np.asarray(loss_w, np.float32),
                                  np.asarray(loss_t, np.float32))
    for key in grads_w:
        for a, b in zip(jax.tree.leaves(grads_t[key]),
                        jax.tree.leaves(grads_w[key])):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                err_msg=f"{arch} bucket {key} kernel={use_kernel}")


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_cnn_tape_grads_bitexact_any_batch(seed):
    """Hypothesis leg of the satellite: the CNN per-layer VJP tape grads
    match one whole value_and_grad bit-for-bit on any batch."""
    cfg = C.get("chaos-small")
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(3))
    batch = _batch(cfg, jax.random.key(seed), B=4)
    loss_w, _, grads_w = jax.jit(ops.loss_and_grads)(params, batch)
    loss_t, _, _, grads_t = jax.jit(
        lambda p, b: ops.loss_and_grads(p, b, tape=lambda *_: None))(
            params, batch)
    np.testing.assert_array_equal(np.asarray(loss_w), np.asarray(loss_t))
    for key in grads_w:
        for a, b in zip(jax.tree.leaves(grads_t[key]),
                        jax.tree.leaves(grads_w[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# optimizer bucket-state slicing
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [
    lambda: sgd(lambda s: 0.1),
    lambda: sgd(lambda s: 0.1, momentum=0.9),
    lambda: adamw(lambda s: 1e-3),
])
def test_optimizer_slice_merge_roundtrip(make_opt):
    cfg = C.get("chaos-small")
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))
    opt = make_opt()
    state = opt.init(params)
    rebuilt = state
    for bucket in ops.bucket_spec():
        sliced = opt.slice_state(state, bucket.keys)
        assert sorted(sliced) == sorted(state)
        for tree in sliced.values():
            assert sorted(tree) == sorted(bucket.keys)
        rebuilt = opt.merge_state(rebuilt, bucket.keys, sliced)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adamw_pre_apply_split_matches_apply():
    """apply == apply_raw ∘ pre_apply: the global clip is the ONLY coupled
    piece, so per-bucket apply_raw after one pre_apply is the whole
    update."""
    cfg = C.get("chaos-small")
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))
    opt = adamw(lambda s: 1e-3)
    state = opt.init(params)
    _, _, grads = ops.loss_and_grads(params, _batch(cfg, jax.random.key(1)))
    p1, s1 = opt.apply(params, grads, state, 0)
    p2, s2 = opt.apply_raw(params, opt.pre_apply(grads), state, 0)
    for a, b in zip(jax.tree.leaves((p1, s1)), jax.tree.leaves((p2, s2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert sgd(lambda s: 0.1).pre_apply is None
    assert adamw(lambda s: 1e-3, grad_clip=None).pre_apply is None


# ---------------------------------------------------------------------------
# per-bucket compression residual round-trip
# ---------------------------------------------------------------------------
def test_layerwise_compress_residual_roundtrip_per_bucket():
    """The per-bucket error-feedback walk (bucket_exchange slicing the
    residual bucket by bucket) merges back to EXACTLY the whole-tree
    compress_grads result on the same gradients — per-leaf quantisation is
    bucket-independent — and a real layerwise step carries it end-to-end."""
    from repro.train.sync import StepContext, get_strategy

    cfg = C.get("chaos-small")
    ops = get_ops(cfg)
    sync = SyncConfig("bsp", layerwise=True, compress=True)
    opt = sgd(lambda s: 0.05)
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    batch = _batch(cfg, jax.random.key(1), B=8)
    strat = get_strategy(sync)
    ctx = StepContext(optimizer=opt)

    @jax.jit
    def both(params, batch, sync_state):
        _, _, _, grads = ops.loss_and_grads(params, batch,
                                            tape=lambda *_: None)
        exchange_bucket, finish = strat.bucket_exchange(ctx, sync_state, 0)
        for b in reversed(ops.bucket_spec()):
            exchange_bucket(b, b.view(grads))
        per_bucket = finish(grads)["residual"]
        _, whole = compress_grads(grads, sync_state["residual"])
        return per_bucket, whole

    per_bucket, whole = both(state["params"], batch, state["sync"])
    for a, b in zip(jax.tree.leaves(per_bucket), jax.tree.leaves(whole)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert any(np.any(np.asarray(l) != 0) for l in jax.tree.leaves(whole))

    # end-to-end: the compiled layerwise step carries the same residual
    # (cross-program comparison -> the repo's standard 1-ulp tolerance)
    step = jax.jit(make_train_step(cfg, sync, opt))
    new_state, _ = step(state, batch)
    for a, b in zip(jax.tree.leaves(new_state["sync"]["residual"]),
                    jax.tree.leaves(per_bucket)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# ring_dtype
# ---------------------------------------------------------------------------
def test_ring_dtype_bf16_halves_ring_and_stays_noop_exact():
    cfg = C.get("chaos-small")
    opt = sgd(lambda s: 0.05)
    sync32 = SyncConfig("chaos", staleness=2)
    sync16 = SyncConfig("chaos", staleness=2, ring_dtype="bfloat16")
    s32 = init_train_state(cfg, jax.random.key(0), sync32, opt)
    s16 = init_train_state(cfg, jax.random.key(0), sync16, opt)
    bytes32 = sum(l.size * l.dtype.itemsize
                  for l in jax.tree.leaves(s32["sync"]["hist"]))
    bytes16 = sum(l.size * l.dtype.itemsize
                  for l in jax.tree.leaves(s16["sync"]["hist"]))
    assert bytes16 * 2 == bytes32
    for slot in s16["sync"]["hist"].values():
        assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(slot))

    # first τ steps apply the zero ring — exact no-ops in any ring dtype
    step = jax.jit(make_train_step(cfg, sync16, opt))
    batch = _batch(cfg, jax.random.key(1), B=8)
    p0 = jax.tree.map(np.asarray, s16["params"])
    for _ in range(2):
        s16, _ = step(s16, batch)
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(s16["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # step τ+1 applies the (bf16-quantised) step-1 exchange: close to the
    # exact f32-ring update within bf16 tolerance
    step32 = jax.jit(make_train_step(cfg, sync32, opt))
    r32 = init_train_state(cfg, jax.random.key(0), sync32, opt)
    for _ in range(3):
        r32, _ = step32(r32, batch)
    s16, _ = step(s16, batch)
    for a, b in zip(jax.tree.leaves(s16["params"]),
                    jax.tree.leaves(r32["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_ring_dtype_unknown_name_rejected():
    with pytest.raises(TypeError):
        SyncConfig("chaos", staleness=1, ring_dtype="not-a-dtype")
