"""Worker-mesh scaling semantics (DESIGN.md §4, paper Result 3 harness).

The contract: the worker-mesh superstep route (shard_map over
``make_host_mesh(n)``) computes

  bsp      - BIT-IDENTICAL updates for every worker count dividing
             ``WorkerConfig.logical_shards`` on identical global batches
             (the fixed-shape gathered shard reduction), so checkpoints are
             worker-count-invariant;
  chaos    - the staleness-1 delayed update rule
             w_{t+1} = w_t - lr * mean_i g_i(w_{t-1});
  localsgd - purely local steps with a K-boundary parameter average that
             equals the mean of the per-worker weights.

Worker-model tests run in a subprocess with 8 forced host devices (the env
flag must be set before jax initialises; conftest must NOT set it
globally)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, n_dev: int = 8, env_extra=None):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    env.update(env_extra or {})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


_SETUP = """
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.core.types import WorkerConfig
    from repro.data.mnist import make_dataset
    from repro.data.pipeline import ImagePipeline
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import put_worker_sharded
    from repro.train.step import (init_worker_state, make_optimizer,
                                  make_worker_superstep)

    cfg = C.get("chaos-small")
    imgs, labels = make_dataset(128, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")

    def build(n, mode, opt=None, local_steps=2, cfg=cfg, staleness=None):
        worker = WorkerConfig(workers=n)
        mesh = make_host_mesh(n)
        if staleness is None:
            # localsgd's staleness picks the tau-ring depth since the
            # overlap PR; these pins cover the classic blocking boundary
            # average, so tau=0 unless a test opts in
            staleness = 0 if mode == "localsgd" else 1
        sync = SyncConfig(mode, local_steps=local_steps,
                          axis_name=worker.axis, staleness=staleness)
        opt = opt or make_optimizer(cfg, total_steps=64)
        fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
        state = init_worker_state(cfg, jax.random.key(0), sync, worker, opt)
        return fn, state, mesh, worker

    def run(n, mode, steps=6, K=2, opt=None, cfg=cfg):
        fn, state, mesh, worker = build(n, mode, opt, cfg=cfg)
        losses = []
        for s in range(0, steps, K):
            state, m = fn(state, put_worker_sharded(pipe, s, K, mesh,
                                                    worker))
            losses.extend(np.asarray(m["loss"]).tolist())
        return jax.tree.map(np.asarray, state), losses

    def assert_tree_equal(a, b, msg=""):
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=msg)
"""


def test_bsp_bitexact_across_worker_counts():
    """bsp at N=1, N=2 and N=4 on identical global batches: full TrainState
    AND the logged (K,) loss vectors bit-exact — the worker count is purely
    an execution detail (acceptance criterion)."""
    out = _run_sub(_SETUP + """
    s1, l1 = run(1, "bsp")
    s2, l2 = run(2, "bsp")
    s4, l4 = run(4, "bsp")
    assert int(s1["step"]) == 6
    assert_tree_equal(s1, s2, "bsp N=1 vs N=2")
    assert_tree_equal(s1, s4, "bsp N=1 vs N=4")
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l4))
    print("OK", l1[-1])
    """)
    assert "OK" in out


def test_chaos_hogwild_update_rule_at_n2():
    """True CHAOS semantics on the worker mesh (staleness τ=1, N=2): each
    worker applies its OWN additive term of the global gradient mean
    instantly every step and folds peers' terms in one step late —
    w^i_{t+1} = w^i_t - lr * (own_i(w^i_t) + remote_i(t-1)) — verified for
    3 steps against a plain-JAX reference that implements the recurrence
    shard by shard."""
    out = _run_sub(_SETUP + """
    from repro.models.api import get_ops
    from repro.optim import sgd

    lr = 0.05
    opt = sgd(lambda s: lr)
    ops = get_ops(cfg)
    N, S = 2, 8  # workers, logical shards (batch 8 -> 1 image per shard)

    fn, state, mesh, worker = build(N, "chaos", opt=opt)
    assert worker.logical_shards == S

    # reference: per-worker params, per-shard single-image gradients
    def shard_grad(p, img, lab):
        b = {"images": img[None], "labels": lab[None]}
        return jax.grad(lambda p: ops.loss(p, b)[0])(p)

    p_ref = [ops.init(jax.random.key(0)) for _ in range(N)]
    remote_prev = [jax.tree.map(lambda x: jnp.zeros_like(x), p_ref[0])
                   for _ in range(N)]
    for t in range(3):
        b = pipe.batch_at(t)
        own = []
        for w in range(N):
            lanes = range(w * S // N, (w + 1) * S // N)
            gs = [shard_grad(p_ref[w], b["images"][s], b["labels"][s])
                  for s in lanes]
            own.append(jax.tree.map(
                lambda *g: sum(g[1:], g[0]) / S, *gs))
        gmean = jax.tree.map(lambda *g: sum(g[1:], g[0]), *own)
        for w in range(N):
            p_ref[w] = jax.tree.map(
                lambda p, o, r: p - lr * (o + r),
                p_ref[w], own[w], remote_prev[w])
            remote_prev[w] = jax.tree.map(lambda gm, o: gm - o,
                                          gmean, own[w])

    for t in range(3):
        state, _ = fn(state, put_worker_sharded(pipe, t, 1, mesh, worker))
    got = jax.tree.map(np.asarray, state["params"])
    for w in range(N):
        for a, b_ in zip(jax.tree.leaves(got),
                         jax.tree.leaves(jax.tree.map(np.asarray,
                                                      p_ref[w]))):
            np.testing.assert_allclose(a[w], b_, atol=1e-5, rtol=1e-5)
    # workers genuinely diverged (arbitrary-order updates)
    leaf = jax.tree.leaves(got)[0]
    assert not np.allclose(leaf[0], leaf[1]), "workers must diverge"
    print("OK")
    """)
    assert "OK" in out


def test_localsgd_boundary_average_equals_worker_mean_at_n4():
    """localsgd at N=4 with local_steps=2: workers diverge off-boundary,
    and the boundary parameters equal the MEAN of the per-worker weights
    each worker would hold without the average."""
    out = _run_sub(_SETUP + """
    # reference: local_steps so large no boundary fires in 2 steps
    fn_ref, s_ref, mesh, worker = build(4, "localsgd", local_steps=1000)
    fn_avg, s_avg, _, _ = build(4, "localsgd", local_steps=2)
    b = put_worker_sharded(pipe, 0, 2, mesh, worker)
    s_ref, _ = fn_ref(s_ref, b)
    b = put_worker_sharded(pipe, 0, 2, mesh, worker)
    s_avg, _ = fn_avg(s_avg, b)

    ref_leaves = [np.asarray(x) for x in jax.tree.leaves(s_ref["params"])]
    avg_leaves = [np.asarray(x) for x in jax.tree.leaves(s_avg["params"])]
    diverged = any(not np.allclose(x[0], x[1]) for x in ref_leaves)
    assert diverged, "workers must diverge between localsgd boundaries"
    for r, a in zip(ref_leaves, avg_leaves):
        mean = r.mean(axis=0)
        for wkr in range(4):
            np.testing.assert_allclose(a[wkr], mean, atol=1e-6, rtol=1e-6)
    print("OK")
    """)
    assert "OK" in out


def test_worker_kernel_path_bitexact_n1_vs_n2():
    """The Pallas kernel path composes with the worker mesh: bsp through
    use_kernel=True is bit-exact N=1 vs N=2 (per-shard kernel launches see
    identical shapes regardless of worker count)."""
    out = _run_sub(_SETUP + """
    import dataclasses
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    s1, l1 = run(1, "bsp", steps=2, K=2, cfg=kcfg)
    s2, l2 = run(2, "bsp", steps=2, K=2, cfg=kcfg)
    assert np.all(np.isfinite(np.asarray(l1)))
    assert_tree_equal(s1, s2, "kernel-path bsp N=1 vs N=2")
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    print("OK", l1)
    """)
    assert "OK" in out


def _run_driver(args, ckpt_dir, n_dev=8, die_at=None):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "chaos-small", "--steps", "8", "--superstep", "4",
           "--ckpt-every", "4", "--ckpt-dir", ckpt_dir] + args
    if die_at is not None:
        cmd += ["--die-at-step", str(die_at)]
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=900)


def test_driver_die_resume_n4_bitexact_and_worker_count_invariant(tmp_path):
    """Driver-level fault tolerance on the worker mesh: die at a superstep
    boundary under N=4, resume — with a DIFFERENT worker count (N=2) — and
    the final checkpoint must be bit-identical to an uninterrupted N=4
    run's (bsp checkpoints are worker-count-invariant)."""
    a, b = str(tmp_path / "a"), str(tmp_path / "b")

    first = _run_driver(["--workers", "4"], a, die_at=4)
    assert first.returncode == 17, first.stderr[-2000:]
    assert "simulated preemption at step 4" in first.stdout

    second = _run_driver(["--workers", "2"], a)
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed from step 4" in second.stdout

    straight = _run_driver(["--workers", "4"], b)
    assert straight.returncode == 0, straight.stderr[-2000:]

    fa = np.load(os.path.join(a, "step_0000000008", "arrays.npz"))
    fb = np.load(os.path.join(b, "step_0000000008", "arrays.npz"))
    assert fa.files == fb.files
    for k in fa.files:
        np.testing.assert_array_equal(fa[k], fb[k])


def test_localsgd_checkpoint_pins_worker_count(tmp_path):
    """localsgd state genuinely diverges per worker, so its (N, ...)-stacked
    checkpoint must REFUSE to resume under a different worker count (a
    silent x[0] unstack would drop workers' state) — while resuming at the
    SAME count works."""
    d = str(tmp_path / "lsgd")

    first = _run_driver(["--workers", "4", "--sync", "localsgd"], d,
                        die_at=4)
    assert first.returncode == 17, first.stderr[-2000:]

    bad = _run_driver(["--workers", "2", "--sync", "localsgd"], d)
    assert bad.returncode != 0
    assert "different state layout" in bad.stderr

    ok = _run_driver(["--workers", "4", "--sync", "localsgd"], d)
    assert ok.returncode == 0, ok.stderr[-2000:]
    assert "resumed from step 4" in ok.stdout


def test_make_host_mesh_rejects_oversubscription():
    """Satellite fix: asking for more workers than visible devices must be
    a clear error naming the XLA_FLAGS remedy, not a silent truncation."""
    from repro.launch.mesh import make_host_mesh
    import jax

    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_host_mesh(too_many)


def test_worker_config_validation():
    from repro.core.types import WorkerConfig

    with pytest.raises(ValueError, match="divide"):
        WorkerConfig(workers=3, logical_shards=8)
    with pytest.raises(ValueError, match=">= 1"):
        WorkerConfig(workers=0)
    with pytest.raises(ValueError, match="divisible by"):
        WorkerConfig(workers=2, logical_shards=8).validate_batch(12)
    w = WorkerConfig(workers=4, logical_shards=8)
    assert w.shards_per_worker == 2
