"""Autotune on-disk cache integrity under concurrent writers (satellite:
two processes tuning the same net must never corrupt the JSON cache).

The regression this pins: ``_save_disk`` used a SHARED ``path + ".tmp"``
scratch name, so two concurrent writers could interleave bytes in one tmp
file before the atomic rename — now each writer renames from a
process-unique ``mkstemp`` file, so the cache file is always one writer's
complete, parseable document (individual last-writer key races are
acceptable; a torn file is not)."""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WRITER = """
    import os, sys
    from repro.kernels import autotune as AT

    tag = sys.argv[1]
    for i in range(40):
        # force a fresh disk read-merge-write cycle per record, maximising
        # writer interleaving
        AT.clear_memory_cache()
        AT.record(f"conv_fwd|{tag}|shape{i}|float32|cpu|interp=1",
                  {"batch_block": 8, "row_block": i + 1}, 100.0 + i, {},
                  iters=1)
    print("DONE", tag)
"""


def test_concurrent_writers_never_corrupt_cache(tmp_path):
    cache = str(tmp_path / "autotune.json")
    env = dict(os.environ, PYTHONPATH=SRC, REPRO_AUTOTUNE_CACHE=cache)
    procs = [subprocess.Popen(
        [sys.executable, "-c", textwrap.dedent(_WRITER), tag],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for tag in ("writerA", "writerB")]
    for p in procs:
        out, err = p.communicate(timeout=300)
        assert p.returncode == 0, err[-2000:]
        assert "DONE" in out

    # the file must be one complete JSON document...
    with open(cache) as f:
        data = json.load(f)
    # ...containing entries from BOTH writers (merge-on-write), and no
    # leftover tmp scratch files
    tags = {k.split("|")[1] for k in data}
    assert tags == {"writerA", "writerB"}, tags
    assert len(data) >= 40, f"lost too many entries: {len(data)}"
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert not leftovers, leftovers


def test_record_roundtrips_through_unique_tmp(tmp_path):
    """Single-writer sanity on the new write path: record -> reload."""
    cache = str(tmp_path / "cache.json")
    os.environ["REPRO_AUTOTUNE_CACHE"] = cache
    try:
        from repro.kernels import autotune as AT
        AT.clear_memory_cache()
        AT.record("op|plain|1_2|float32|cpu|interp=1",
                  {"batch_block": 4}, 7.0, {"{}": 7.0}, iters=2)
        AT.clear_memory_cache()
        entry = AT.lookup("op|plain|1_2|float32|cpu|interp=1")
        assert entry is not None and entry["config"] == {"batch_block": 4}
    finally:
        del os.environ["REPRO_AUTOTUNE_CACHE"]
        from repro.kernels import autotune as AT
        AT.clear_memory_cache()
