"""Numerical-equivalence tests for the hand-derived algorithms:
flash attention (fwd+bwd), chunked WKV6, chunked Mamba2 SSD, MLA absorbed
decode, and decode-vs-forward parity for every decode family."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import layers as L
from repro.models.api import get_ops


# ---------------------------------------------------------------------------
# flash attention vs naive
# ---------------------------------------------------------------------------
def naive_attention(q, k, v, causal, scale=None):
    B, T, Hq, D = q.shape
    Hkv, Dv = k.shape[2], v.shape[3]
    G = Hq // Hkv
    scale = scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((T, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, Dv).astype(q.dtype)


@pytest.mark.parametrize("B,T,Hq,Hkv,D,Dv,causal,bk", [
    (2, 64, 4, 2, 16, 16, True, 16),
    (1, 37, 3, 3, 8, 12, True, 16),       # ragged + MLA-style Dv != D
    (2, 128, 8, 2, 32, 32, False, 32),
    (1, 100, 4, 4, 16, 16, True, 7),      # non-dividing block
])
def test_flash_attention_fwd_bwd(B, T, Hq, Hkv, D, Dv, causal, bk):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Hkv, Dv), jnp.float32)
    o1 = L.flash_attention(q, k, v, causal=causal, block_k=bk)
    o2 = naive_attention(q, k, v, causal)
    np.testing.assert_allclose(o1, o2, atol=2e-5, rtol=2e-5)
    f1 = lambda *a: L.flash_attention(*a, causal=causal, block_k=bk).sum()
    f2 = lambda *a: naive_attention(*a, causal).sum()
    g1 = jax.grad(f1, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)


def test_flash_attention_causality():
    """Output at position t must not depend on tokens > t."""
    ks = jax.random.split(jax.random.key(1), 3)
    B, T, H, D = 1, 32, 2, 8
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    o1 = L.flash_attention(q, k, v, causal=True, block_k=8)
    k2 = k.at[:, T // 2:].set(99.0)
    v2 = v.at[:, T // 2:].set(-99.0)
    o2 = L.flash_attention(q, k2, v2, causal=True, block_k=8)
    np.testing.assert_allclose(o1[:, :T // 2], o2[:, :T // 2], atol=1e-6)


# ---------------------------------------------------------------------------
# WKV6 chunked vs naive recurrence
# ---------------------------------------------------------------------------
def naive_wkv(r, k, v, w, u):
    B, T, H, D = r.shape
    S = jnp.zeros((B, H, D, D), jnp.float32)
    ys = []
    for t in range(T):
        kt, vt, rt, wt = (x[:, t].astype(jnp.float32)
                          for x in (k, v, r, w))
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt,
                       S + u.astype(jnp.float32)[None, :, :, None] * kv)
        ys.append(y)
        S = S * wt[..., None] + kv
    return jnp.stack(ys, 1), S


@pytest.mark.parametrize("T", [64, 128, 37 * 0 + 192])
def test_wkv_chunked_matches_recurrence(T):
    from repro.models.rwkv6 import wkv_chunked
    B, H, D = 2, 2, 8
    ks = jax.random.split(jax.random.key(2), 5)
    r = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D))
    w = jnp.exp(-jnp.exp(jnp.clip(
        jax.random.normal(ks[3], (B, T, H, D)), None, 0.0)))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    y1, S1 = wkv_chunked(r, k, v, w, u)
    y2, S2 = naive_wkv(r, k, v, w, u)
    np.testing.assert_allclose(y1, y2, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(S1, S2, atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Mamba2 chunked SSD vs naive recurrence
# ---------------------------------------------------------------------------
def naive_ssd(xh, dt, A, B_, C_, D):
    Bsz, T, H, P = xh.shape
    N = B_.shape[-1]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    S = jnp.zeros((Bsz, H, N, P), jnp.float32)
    ys = []
    for t in range(T):
        dA = jnp.exp(dt[:, t] * A)                        # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, t], B_[:, t].astype(jnp.float32),
                         xh[:, t].astype(jnp.float32))
        S = S * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", C_[:, t].astype(jnp.float32), S)
        ys.append(y + xh[:, t].astype(jnp.float32) * D[None, :, None])
    return jnp.stack(ys, 1)


@pytest.mark.parametrize("H", [2, 32])  # 32 exercises HEAD_BLOCK splitting
def test_ssd_chunked_matches_recurrence(H):
    from repro.models import mamba2
    Bsz, T, P, N = 2, 256, 4, 8
    ks = jax.random.split(jax.random.key(3), 5)
    xh = jax.random.normal(ks[0], (Bsz, T, H, P))
    dt = jax.random.normal(ks[1], (Bsz, T, H)) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (Bsz, T, N)) * 0.5
    C_ = jax.random.normal(ks[4], (Bsz, T, N)) * 0.5
    D = jnp.ones((H,))
    y1 = mamba2.ssd_chunked(xh, dt, A, B_, C_, D)
    y2 = naive_ssd(xh, dt, A, B_, C_, D)
    np.testing.assert_allclose(y1, y2, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# decode == forward parity (every decode family)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3-14b", "minicpm3-4b", "rwkv6-1.6b",
                                  "zamba2-1.2b", "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the teacher-forced forward
    logits — this exercises KV caches, MLA absorption, SSM states, and the
    shared-attention hybrid cache in one go."""
    cfg = C.smoke(arch)
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))
    B, T = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    full_logits, _ = ops.forward(params, tokens)

    cache = ops.init_cache(B, T + 4)
    dec = []
    for t in range(T):
        logits, cache = ops.decode(params, cache, tokens[:, t:t + 1], t)
        dec.append(logits[:, 0])
    dec = jnp.stack(dec, 1)
    # bf16 params + different contraction orders: tolerate modest error on
    # the (unnormalised) logits
    np.testing.assert_allclose(
        np.asarray(dec[:, :, :cfg.vocab_size], np.float32),
        np.asarray(full_logits[:, :, :cfg.vocab_size], np.float32),
        atol=0.15, rtol=0.15)


def test_mla_absorbed_decode_equivalence():
    """The absorbed MLA decode must equal materialising k/v from the latent
    (up to numerics) — checked via decode-vs-forward argmax agreement."""
    cfg = C.smoke("minicpm3-4b")
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(7))
    B, T = 1, 12
    tokens = jax.random.randint(jax.random.key(8), (B, T), 0, cfg.vocab_size)
    full_logits, _ = ops.forward(params, tokens)
    cache = ops.init_cache(B, T)
    for t in range(T):
        logits, cache = ops.decode(params, cache, tokens[:, t:t + 1], t)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(logits[:, 0, :cfg.vocab_size])),
        np.argmax(np.asarray(full_logits[:, -1, :cfg.vocab_size])))


def test_fused_ce_matches_plain():
    B, T, d, V = 2, 32, 16, 64
    ks = jax.random.split(jax.random.key(4), 3)
    x = jax.random.normal(ks[0], (B, T, d))
    W = jax.random.normal(ks[1], (V, d)) * 0.2
    labels = jax.random.randint(ks[2], (B, T), 0, 50)
    plain = L.cross_entropy(jnp.einsum("btd,vd->btv", x, W), labels, 50)
    fused = L.fused_ce(x, W, labels, 50, n_chunks=4)
    np.testing.assert_allclose(plain, fused, atol=1e-5, rtol=1e-5)
    g1 = jax.grad(lambda x: L.cross_entropy(
        jnp.einsum("btd,vd->btv", x, W), labels, 50))(x)
    g2 = jax.grad(lambda x: L.fused_ce(x, W, labels, 50, n_chunks=4))(x)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-5)


def test_flash_bwd_no_nan_with_extreme_masked_scores():
    """Regression: masked (future) scores far above a row's lse used to
    overflow exp() in the flash backward and poison gradients with NaN
    (inf * 0). Construct repeated-key sequences with huge dot products."""
    B, T, H, D = 2, 32, 2, 8
    base = jax.random.normal(jax.random.key(0), (B, 1, H, D)) * 6.0
    q = jnp.broadcast_to(base, (B, T, H, D))  # identical rows -> big s
    k = q * 1.5
    v = jax.random.normal(jax.random.key(1), (B, T, H, D))
    g = jax.grad(lambda q, k, v: L.flash_attention(
        q, k, v, causal=True, block_k=8).astype(jnp.float32).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for t in g:
        assert bool(jnp.all(jnp.isfinite(t))), "NaN in flash backward"


def test_hybrid_group_scan_matches_loop():
    """Zamba group-scan (5 mamba + shared attn per period) must equal the
    python-loop execution of the same params."""
    import dataclasses
    cfg0 = C.smoke("zamba2-1.2b")
    cfg1 = dataclasses.replace(cfg0, scan_layers=True)
    ops0, ops1 = get_ops(cfg0), get_ops(cfg1)
    params = ops0.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                cfg0.vocab_size)
    l0, _ = ops0.forward(params, tokens)
    l1, _ = ops1.forward(params, tokens)
    # bf16 + different fusion order: small absolute noise on logits
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), atol=0.05)
