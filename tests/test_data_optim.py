"""Data-pipeline determinism/resume + optimizer correctness + property
tests on core numerics (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from tests._hypothesis_compat import given, settings, st

from repro.core.schedule import make_lr_fn
from repro.data.mnist import make_dataset, splits
from repro.data.pipeline import ImagePipeline, TokenPipeline
from repro.models import layers as L
from repro.optim import adamw, sgd


# -- data -------------------------------------------------------------------
def test_token_pipeline_deterministic_resume():
    p = TokenPipeline(vocab_size=512, batch=4, seq_len=32, seed=3)
    b1 = p.batch_at(17)
    b2 = p.batch_at(17)  # replay == resume
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 512
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_mnist_separable_and_deterministic():
    (xi, yi), _, (xt, yt) = splits(512, 64, 256, seed=0)
    xi2, yi2 = make_dataset(512, seed=0)
    np.testing.assert_array_equal(xi, xi2)
    # nearest-centroid classifier should beat chance handily
    cents = np.stack([xi[yi == d].mean(0).ravel() for d in range(10)])
    preds = np.argmin(
        ((xt.reshape(len(xt), -1)[:, None] - cents[None]) ** 2).sum(-1), -1)
    acc = (preds == yt).mean()
    assert acc > 0.5, acc


def test_worker_queue_covers_all_images_once_per_epoch():
    imgs, labels = make_dataset(64, seed=1)
    p = ImagePipeline(imgs, labels, batch=8)
    b = p.worker_batches(0, n_workers=4, per_worker=16)
    assert b["images"].shape == (4, 16, 29, 29, 1)
    # 4*16 = 64 picks cover every index exactly once (shared queue)
    got = b["labels"].ravel()
    assert sorted(
        np.random.default_rng(np.random.SeedSequence([0, 0])).permutation(64)
    ) == list(range(64))


# -- optimizers ---------------------------------------------------------------
def test_sgd_momentum_quadratic():
    opt = sgd(lambda s: 0.1, momentum=0.9)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    for step in range(250):
        g = {"x": 2 * params["x"]}
        params, state = opt.apply(params, g, state, step)
    assert abs(float(params["x"])) < 1e-3


def test_adamw_converges_and_moment_dtype():
    opt = adamw(lambda s: 0.05, weight_decay=0.0, moment_dtype="bfloat16")
    params = {"x": jnp.asarray(3.0)}
    state = opt.init(params)
    assert state["m"]["x"].dtype == jnp.bfloat16
    for step in range(300):
        g = {"x": 2 * params["x"]}
        params, state = opt.apply(params, g, state, step)
    assert abs(float(params["x"])) < 1e-2


def test_adamw_grad_clip():
    opt = adamw(lambda s: 0.0, grad_clip=1.0)  # lr 0: only states move
    params = {"x": jnp.ones((4,))}
    state = opt.init(params)
    _, state = opt.apply(params, {"x": jnp.full((4,), 100.0)}, state, 0)
    # clipped global norm 1.0 -> m = (1-b1)*g_clipped, |g| = 0.5 each
    np.testing.assert_allclose(np.asarray(state["m"]["x"]),
                               0.1 * 0.5 * np.ones(4), rtol=1e-3)


# -- schedules ---------------------------------------------------------------
def test_paper_decay_schedule():
    fn = make_lr_fn("decay", base_lr=1e-3, steps_per_epoch=100,
                    decay_factor=0.9)
    np.testing.assert_allclose(float(fn(0)), 1e-3, rtol=1e-5)
    np.testing.assert_allclose(float(fn(100)), 9e-4, rtol=1e-5)
    np.testing.assert_allclose(float(fn(1000)), 1e-3 * 0.9 ** 10, rtol=1e-5)


def test_wsd_schedule_shape():
    fn = make_lr_fn("wsd", base_lr=1e-3, total_steps=1000, warmup=50)
    assert 0.0 < float(fn(0)) <= 1e-3 / 25  # nonzero first step
    np.testing.assert_allclose(float(fn(50)), 1e-3, rtol=1e-5)
    np.testing.assert_allclose(float(fn(800)), 1e-3, rtol=1e-5)  # stable
    assert float(fn(999)) < 2.1e-4          # decayed ~10x
    assert float(fn(999)) >= 1e-4 * 0.9


# -- property tests on numerics ----------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(2, 24), st.integers(2, 16))
def test_rms_norm_invariants(b, t, d):
    x = jax.random.normal(jax.random.key(b * 100 + t), (b, t, d))
    g = jnp.ones((d,))
    y = L.rms_norm(x, g)
    # unit RMS output (up to the eps regulariser)
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, atol=3e-2)
    # scale invariance (eps makes tiny-norm rows differ slightly)
    y2 = L.rms_norm(x * 7.3, g)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 32), st.integers(1, 8))
def test_rope_preserves_norm_and_relative_positions(t, h):
    d = 16
    x = jax.random.normal(jax.random.key(t * 10 + h), (1, t, h, d))
    pos = jnp.arange(t)[None, :]
    y = L.rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, d))
    def dot_at(i, j):
        qi = L.rope(q, jnp.full((1, 1), i), theta=1e4)
        kj = L.rope(k, jnp.full((1, 1), j), theta=1e4)
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(10, 8), rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_cross_entropy_bounds(seed):
    key = jax.random.key(seed)
    logits = jax.random.normal(key, (2, 8, 32)) * 3
    labels = jax.random.randint(key, (2, 8), 0, 24)
    ce = float(L.cross_entropy(logits, labels, 24))
    assert ce > 0
    # CE with vocab mask >= CE against full support... and finite
    assert np.isfinite(ce)
