"""Elastic worker resize (DESIGN.md §7): the ResizeController's degradation
ladder, the ``reslot_stacked`` shrink/grow rule, and the driver-level
acceptance criterion — kill a worker mid-epoch and the loss sequence of a
bsp/chaos-replicated run continues BIT-IDENTICALLY to an uninterrupted run.

Driver tests run the real ``repro.launch.train`` CLI in subprocesses with
forced host devices and assert on its ``--metrics-out`` JSON artifact (the
same artifact CI's preemption-injection smoke uses); pure re-slot logic is
tested in-process.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import WorkerConfig
from repro.launch.faults import FaultPlan
from repro.train.sync import reslot_stacked

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- reslot_stacked unit rules ------------------------------------------------

def test_reslot_shrink_is_group_mean():
    x = jnp.arange(8, dtype=jnp.float32).reshape(4, 2)
    got = reslot_stacked(x, 4, 2)
    want = np.stack([x[:2].mean(0), x[2:].mean(0)])
    np.testing.assert_array_equal(np.asarray(got), want)


def test_reslot_grow_is_copy():
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    got = np.asarray(reslot_stacked(x, 2, 4))
    np.testing.assert_array_equal(got, np.asarray(x)[[0, 0, 1, 1]])


def test_reslot_non_dividing_falls_back_to_global_mean():
    x = jnp.arange(4, dtype=jnp.float32).reshape(4, 1)
    got = np.asarray(reslot_stacked(x, 4, 3))
    np.testing.assert_array_equal(got, np.full((3, 1), 1.5, np.float32))


def test_reslot_preserves_dtype():
    x = jnp.ones((4, 3), jnp.bfloat16)
    assert reslot_stacked(x, 4, 2).dtype == jnp.bfloat16


def test_reslot_rejects_wrong_leading_axis():
    with pytest.raises(ValueError, match="leading"):
        reslot_stacked(jnp.zeros((3, 2)), 4, 2)


def test_clamp_workers_lands_on_divisor():
    w8 = WorkerConfig(workers=4, logical_shards=8)
    assert w8.clamp_workers(3) == 2      # 3 does not divide 8
    assert w8.clamp_workers(8) == 8
    assert w8.clamp_workers(0) == 1
    w12 = WorkerConfig(workers=4, logical_shards=12)
    assert w12.clamp_workers(3) == 3     # a true 4 -> 3 shrink


def test_resize_state_rejects_logical_shard_change():
    from repro.core.chaos import SyncConfig
    from repro.train.sync import get_strategy
    strat = get_strategy(SyncConfig("bsp"))
    with pytest.raises(ValueError, match="logical_shards"):
        strat.resize_state({}, WorkerConfig(4, logical_shards=8),
                           WorkerConfig(2, logical_shards=4))


# -- FaultPlan spec grammar ---------------------------------------------------

def test_fault_plan_parses_and_is_one_shot():
    plan = FaultPlan.from_spec("kill@6:to=3,stall@4:ms=1,resizefail@2")
    assert plan.membership_event(5, 4) is None   # boundary below threshold
    assert plan.membership_event(6, 4) == 3
    assert plan.membership_event(8, 4) is None   # one-shot
    assert plan.stall(4) > 0 and plan.stall(4) == 0.0
    assert plan.resize_poison(2) and not plan.resize_poison(2)
    assert [e["kind"] for e in plan.log] == ["kill", "stall", "resizefail"]


def test_fault_plan_kill_defaults_to_n_minus_one():
    plan = FaultPlan.from_spec("kill@0")
    assert plan.membership_event(0, 4) == 3


def test_fault_plan_rejects_unknown_kind_and_missing_anchor():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.from_spec("explode@3")
    with pytest.raises(ValueError, match="anchor"):
        FaultPlan.from_spec("kill")
    assert FaultPlan.from_spec(None) is None
    assert FaultPlan.from_spec("") is None


def test_fault_plan_same_seed_same_torn_byte(tmp_path):
    for p in ("a", "b"):
        (tmp_path / p).mkdir()
        (tmp_path / p / "arrays.npz").write_bytes(b"x" * 1000)
    cuts = []
    for p in ("a", "b"):
        plan = FaultPlan.from_spec("torn@1", seed=7)
        plan.on_checkpoint_written(1, str(tmp_path / p))
        cuts.append(plan.log[0]["torn_at_byte"])
    assert cuts[0] == cuts[1]


# -- driver-level resize (the acceptance criterion) ---------------------------

def _run_driver(tmp_path, tag, extra, n_dev=4, expect_rc=0):
    out_json = str(tmp_path / f"{tag}.json")
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "chaos-small", "--steps", "12", "--superstep", "2",
           "--workers", "4", "--logical-shards", "8", "--batch", "8",
           "--metrics-out", out_json] + extra
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == expect_rc, out.stderr[-4000:] + out.stdout[-2000:]
    with open(out_json) as f:
        return json.load(f), out.stdout


def test_kill_mid_run_bit_exact_bsp(tmp_path):
    """THE elastic contract: kill a worker (4 -> 2) mid-run and the bsp
    loss sequence is bit-identical to the uninterrupted run — replicated
    state passes through the resize untouched and the shared-queue batch
    decomposition is keyed by logical_shards, not workers."""
    base, _ = _run_driver(tmp_path, "base", ["--sync", "bsp"])
    kill, log = _run_driver(tmp_path, "kill",
                            ["--sync", "bsp", "--inject", "kill@6:to=2"])
    assert kill["losses"] == base["losses"]          # bit-exact, not close
    assert kill["workers_final"] == 2
    (r,) = kill["resizes"]
    assert (r["path"], r["from"], r["to"]) == ("in-memory", 4, 2)
    assert kill["faults"][0]["kind"] == "kill"
    assert "resized 4 -> 2 worker(s) in-memory" in log


def test_resizefail_falls_back_to_ckpt_restore_still_bit_exact(tmp_path):
    """Rung 2: poison the in-memory resize; the ladder restores the latest
    checkpoint at N'=2 and REPLAYS the gap — replayed losses overwrite
    their originals bit-exactly (worker-count-invariant checkpoints +
    step-keyed pipeline), so the final sequence still matches."""
    base, _ = _run_driver(tmp_path, "base", ["--sync", "bsp"])
    got, log = _run_driver(
        tmp_path, "rf",
        ["--sync", "bsp", "--ckpt-dir", str(tmp_path / "ckpt"),
         "--ckpt-every", "4", "--inject", "kill@6:to=2,resizefail@6"])
    (r,) = got["resizes"]
    assert r["path"] == "ckpt-restore"
    assert r["restart_step"] == 4                    # replayed 4..6
    assert got["losses"] == base["losses"]
    assert "falling back to checkpoint-restore" in log


def test_grow_beyond_devices_degrades_not_crashes(tmp_path):
    """Rung 3: a grow target the device pool cannot back fails both build
    rungs; the run continues at the old N with an actionable log — an
    injected fault must NEVER take down a healthy run."""
    base, _ = _run_driver(tmp_path, "base", ["--sync", "bsp"])
    got, log = _run_driver(tmp_path, "grow",
                           ["--sync", "bsp", "--inject", "kill@6:to=8"])
    (r,) = got["resizes"]
    assert (r["path"], r["to"]) == ("degraded", 4)
    assert got["workers_final"] == 4
    assert got["losses"] == base["losses"]
    assert "DEGRADED" in log and "--workers 8" in log  # actionable remedy


def test_chaos_stacked_resize_runs_to_completion(tmp_path):
    """chaos τ=1 carries worker-stacked params + a staleness ring: the
    resize re-slots every (N, ...) leaf by the documented group-mean rule.
    Defined-but-different: the run completes with finite losses and the
    in-memory rung (no checkpoint involved)."""
    got, _ = _run_driver(
        tmp_path, "chaos",
        ["--sync", "chaos", "--staleness", "1", "--inject", "kill@6:to=2"])
    assert got["resizes"][0]["path"] == "in-memory"
    assert got["workers_final"] == 2
    assert len(got["losses"]) == 12
    assert all(np.isfinite(got["losses"]))


def test_non_dividing_kill_target_clamps(tmp_path):
    """Losing 1 of 4 workers with 8 logical shards cannot land on N'=3;
    the controller clamps to the largest divisor (2) and logs it."""
    got, log = _run_driver(tmp_path, "clamp",
                           ["--sync", "bsp", "--inject", "kill@6"])
    (r,) = got["resizes"]
    assert (r["requested"], r["to"]) == (3, 2)
    assert "does not divide logical_shards=8" in log


def test_probation_clock_resets_on_straggle_and_requests_readmit():
    """ResizeController re-admission bookkeeping in isolation: a
    straggler-reason shrink arms the probation window, a straggle during
    probation resets it, and serving the full window issues a grow request
    back to the pre-eviction worker count."""
    from repro.launch.elastic import ResizeController

    c = ResizeController(None, None, None, WorkerConfig(workers=2), None,
                         readmit_after=2)
    c._maybe_arm_probation(4, 2, "watchdog straggler verdict")
    assert c._probation == (4, 2)
    c.observe_boundary(False)
    assert c._probation == (4, 1)
    c.observe_boundary(True)                      # straggle -> full reset
    assert c._probation == (4, 2)
    c.observe_boundary(False)
    c.observe_boundary(False)                     # window served
    assert c._probation is None
    assert c.take_pending() == (4, "straggler probation served")
    # non-straggler shrinks (kill, signal) never arm probation
    c._maybe_arm_probation(4, 2, "injected kill fault")
    assert c._probation is None


def test_stall_evict_then_probation_readmits(tmp_path):
    """The re-admit round trip through the real driver: a transient
    straggler is evicted (4 -> 2), then after --readmit-after clean
    supersteps the probation clock re-admits it (2 -> 4) — both
    transitions logged, and the bsp loss sequence stays bit-identical to
    an uninterrupted run through BOTH resizes."""
    out_json = str(tmp_path / "readmit.json")
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "chaos-small", "--steps", "20", "--superstep", "1",
              "--workers", "4", "--logical-shards", "8", "--batch", "8",
              "--sync", "bsp"]
    base = subprocess.run(
        common + ["--metrics-out", str(tmp_path / "base.json")],
        capture_output=True, text=True, env=env, timeout=900)
    assert base.returncode == 0, base.stderr[-4000:]
    out = subprocess.run(
        common + ["--inject", "stall@13:ms=400", "--evict-stragglers",
                  "--readmit-after", "2", "--metrics-out", out_json],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "[elastic] probation armed" in out.stdout
    assert "[elastic] probation served" in out.stdout
    with open(out_json) as f:
        got = json.load(f)
    with open(tmp_path / "base.json") as f:
        base_metrics = json.load(f)
    evict, readmit = got["resizes"]
    assert (evict["from"], evict["to"]) == (4, 2)
    assert (readmit["from"], readmit["to"]) == (2, 4)
    assert readmit["path"] == "in-memory"
    assert got["workers_final"] == 4
    assert got["losses"] == base_metrics["losses"]


def test_stall_trips_watchdog_and_evicts(tmp_path):
    """An injected straggler stall lands inside the watchdog's timed
    window; with --evict-stragglers the verdict becomes a membership event
    and the mesh sheds a worker (bsp stays bit-exact through it)."""
    out_json = str(tmp_path / "stall.json")
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    # boundary 13: the watchdog skips 2 warmup observations (compile +
    # donated-buffer re-trace) and z-scores only once 10 are recorded
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "chaos-small", "--steps", "16", "--superstep", "1",
           "--workers", "4", "--logical-shards", "8", "--batch", "8",
           "--sync", "bsp", "--inject", "stall@13:ms=400",
           "--evict-stragglers", "--metrics-out", out_json]
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "[watchdog]" in out.stdout and "straggled" in out.stdout
    with open(out_json) as f:
        got = json.load(f)
    assert got["faults"][0]["kind"] == "stall"
    (r,) = got["resizes"]
    assert (r["path"], r["from"], r["to"]) == ("in-memory", 4, 2)
