"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.chaos import SyncConfig
from repro.models.api import get_ops
from repro.train.step import init_train_state, make_optimizer, make_train_step

ARCHS = C.ASSIGNED


def _batch(cfg, key, B=2, T=16):
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = C.smoke(arch)
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss, metrics = ops.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = C.smoke(arch)
    sync = SyncConfig(mode="bsp")
    opt = make_optimizer(cfg, base_lr=1e-3, total_steps=10)
    step = jax.jit(make_train_step(cfg, sync, opt))
    state = init_train_state(cfg, jax.random.key(0), sync, opt)
    batch = _batch(cfg, jax.random.key(1))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(p0, np.float32),
                           np.asarray(p1, np.float32))
    # no NaNs anywhere in the updated params
    for leaf in jax.tree.leaves(new_state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if C.smoke(a).has_decoder])
def test_decode_step(arch):
    cfg = C.smoke(arch)
    ops = get_ops(cfg)
    if ops.decode is None:
        pytest.skip("no decode path")
    params = ops.init(jax.random.key(0))
    B, S = 2, 32
    cache = ops.init_cache(B, S)
    tokens = jax.random.randint(jax.random.key(1), (B, 1), 0, cfg.vocab_size)
    logits, new_cache = ops.decode(params, cache, tokens, 0)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["chaos-small", "chaos-medium",
                                  "chaos-large"])
def test_cnn_forward(arch):
    cfg = C.get(arch)
    ops = get_ops(cfg)
    params = ops.init(jax.random.key(0))
    imgs = jax.random.uniform(jax.random.key(1), (4, 29, 29, 1))
    labels = jnp.array([0, 1, 2, 3])
    loss, metrics = ops.loss(params, {"images": imgs, "labels": labels})
    assert bool(jnp.isfinite(loss))
    assert 0.0 <= float(metrics["error_rate"]) <= 1.0


def test_cnn_param_counts_match_paper_table2():
    from repro.models.cnn import param_count
    assert param_count(C.get("chaos-small")) == 6405      # 85+1260+4550+510
    assert param_count(C.get("chaos-medium")) == 76040    # 340+20040+54150+1510
    assert param_count(C.get("chaos-large")) == 383160    # 340+30060+216100+135150+1510


def test_full_config_param_counts():
    """Full-config analytic parameter counts are in the advertised range."""
    expect = {
        "qwen3-14b": (13e9, 17e9),
        "mistral-nemo-12b": (11e9, 14e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "qwen3-moe-30b-a3b": (25e9, 35e9),
        "llava-next-34b": (30e9, 38e9),
        "minicpm3-4b": (3.4e9, 5e9),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "whisper-small": (0.15e9, 0.4e9),
        "minicpm-2b": (2.0e9, 3.3e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
    # MoE active params
    a = C.get("qwen3-moe-235b-a22b").active_param_count()
    assert 15e9 <= a <= 30e9
