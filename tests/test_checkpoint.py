"""Fault-tolerance: atomic checkpoints, keep-N GC, exact resume (including
a kill-and-restart integration test through the real training driver)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    s = _state()
    mgr.save(7, s)
    restored, step = mgr.restore(s)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state())
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(1, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    _, step = mgr.restore(_state())
    assert step == 1


def test_no_tmp_dirs_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp")]


def test_kill_and_restart_resumes(tmp_path):
    """Train 30 steps dying at 20 (ckpt every 10), restart, and check the
    driver resumes from step 20 and finishes with the same deterministic
    batches (pipeline keyed by step)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "minicpm-2b", "--steps", "30", "--batch", "2", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"]
    first = subprocess.run(cmd + ["--die-at-step", "20"],
                           capture_output=True, text=True, env=env,
                           timeout=900)
    assert first.returncode == 17, first.stderr[-2000:]
    assert "simulated preemption at step 20" in first.stdout

    second = subprocess.run(cmd, capture_output=True, text=True, env=env,
                            timeout=900)
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed from step 20" in second.stdout
    assert "done" in second.stdout


def test_restore_shape_mismatch_names_leaf_path(tmp_path):
    """Satellite bugfix: a leaf-shape mismatch on restore must name the
    offending leaf's tree path and print expected-vs-actual shapes, not
    raise a bare shape error."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _state())
    bad_template = _state()
    bad_template["params"]["w"] = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError) as ei:
        mgr.restore(bad_template)
    msg = str(ei.value)
    assert "['params']['w']" in msg, msg         # the offending leaf path
    assert "(8, 8)" in msg and "(4, 8)" in msg, msg  # actual vs expected
    assert "different state layout" in msg


def test_torn_write_detected_and_falls_back(tmp_path):
    """A truncated payload (power loss the atomic rename can't save us
    from) fails the manifest length/CRC check; auto restore skips it and
    lands on the newest OLDER checkpoint that validates."""
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    payload = tmp_path / "step_0000000002" / "arrays.npz"
    payload.write_bytes(payload.read_bytes()[:100])   # tear it
    restored, step = mgr.restore(_state())
    assert step == 1
    for a, b in zip(jax.tree.leaves(_state(1)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_torn_write_crc_catches_same_length_corruption(tmp_path):
    """Bit-rot that preserves the byte length is caught by the CRC."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    payload = tmp_path / "step_0000000002" / "arrays.npz"
    raw = bytearray(payload.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    payload.write_bytes(bytes(raw))
    _, step = mgr.restore(_state())
    assert step == 1


def test_pinned_corrupt_step_raises(tmp_path):
    """An explicitly pinned step that fails validation must raise (the
    caller asked for THAT checkpoint), never silently substitute."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, _state())
    payload = tmp_path / "step_0000000002" / "arrays.npz"
    payload.write_bytes(payload.read_bytes()[:50])
    with pytest.raises(ValueError, match="torn payload"):
        mgr.restore(_state(), step=2)


def test_all_candidates_corrupt_raises_filenotfound(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    (tmp_path / "step_0000000001" / "arrays.npz").write_bytes(b"junk")
    with pytest.raises(FileNotFoundError, match="every candidate"):
        mgr.restore(_state())


def test_pre_checksum_checkpoint_still_restores(tmp_path):
    """Back-compat: checkpoints written before the CRC stamp (no crc32 /
    payload_bytes in the manifest) restore with validation skipped."""
    import json
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _state())
    man = tmp_path / "step_0000000003" / "manifest.json"
    meta = json.loads(man.read_text())
    meta.pop("crc32"), meta.pop("payload_bytes")
    man.write_text(json.dumps(meta))
    _, step = mgr.restore(_state())
    assert step == 3


def test_transient_io_errors_retried(tmp_path):
    """The first two payload reads raise an injected OSError; the bounded
    backoff absorbs them and the restore succeeds on the third attempt."""
    from repro.launch.faults import FaultPlan
    plan = FaultPlan.from_spec("io@restore:times=2")
    mgr = CheckpointManager(str(tmp_path), io_retries=3, io_backoff=0.01,
                            fault=plan)
    mgr.save(5, _state())
    _, step = mgr.restore(_state())
    assert step == 5
    assert len([e for e in plan.log if e["kind"] == "io"]) == 2


def test_transient_io_errors_exhaust_retries(tmp_path):
    """More injected failures than the retry budget: the OSError surfaces
    (a genuinely dead filesystem must not hang in a retry loop)."""
    from repro.launch.faults import FaultPlan
    plan = FaultPlan.from_spec("io@restore:times=9")
    mgr = CheckpointManager(str(tmp_path), io_retries=2, io_backoff=0.01,
                            fault=plan)
    mgr.save(5, _state())
    with pytest.raises(OSError, match="injected transient"):
        mgr.restore(_state())


def test_fault_injected_torn_write_roundtrip(tmp_path):
    """End-to-end through the injector: a FaultPlan tears the step-2
    checkpoint as it lands; restore detects and falls back to step 1."""
    from repro.launch.faults import FaultPlan
    plan = FaultPlan.from_spec("torn@2:frac=0.5")
    mgr = CheckpointManager(str(tmp_path), fault=plan)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    assert plan.log[0]["kind"] == "torn"
    _, step = mgr.restore(_state())
    assert step == 1


def test_elastic_restore_under_new_sharding(tmp_path):
    """Restore with explicit shardings (the elastic-rescale path): arrays
    come back on the requested devices."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), s)
    restored, _ = mgr.restore(s, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)
