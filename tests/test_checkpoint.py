"""Fault-tolerance: atomic checkpoints, keep-N GC, exact resume (including
a kill-and-restart integration test through the real training driver)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    s = _state()
    mgr.save(7, s)
    restored, step = mgr.restore(s)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state())
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(1, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1
    _, step = mgr.restore(_state())
    assert step == 1


def test_no_tmp_dirs_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    mgr.save(5, _state())
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp")]


def test_kill_and_restart_resumes(tmp_path):
    """Train 30 steps dying at 20 (ckpt every 10), restart, and check the
    driver resumes from step 20 and finishes with the same deterministic
    batches (pipeline keyed by step)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "minicpm-2b", "--steps", "30", "--batch", "2", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"]
    first = subprocess.run(cmd + ["--die-at-step", "20"],
                           capture_output=True, text=True, env=env,
                           timeout=900)
    assert first.returncode == 17, first.stderr[-2000:]
    assert "simulated preemption at step 20" in first.stdout

    second = subprocess.run(cmd, capture_output=True, text=True, env=env,
                            timeout=900)
    assert second.returncode == 0, second.stderr[-2000:]
    assert "resumed from step 20" in second.stdout
    assert "done" in second.stdout


def test_restore_shape_mismatch_names_leaf_path(tmp_path):
    """Satellite bugfix: a leaf-shape mismatch on restore must name the
    offending leaf's tree path and print expected-vs-actual shapes, not
    raise a bare shape error."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _state())
    bad_template = _state()
    bad_template["params"]["w"] = jnp.zeros((4, 8), jnp.float32)
    with pytest.raises(ValueError) as ei:
        mgr.restore(bad_template)
    msg = str(ei.value)
    assert "['params']['w']" in msg, msg         # the offending leaf path
    assert "(8, 8)" in msg and "(4, 8)" in msg, msg  # actual vs expected
    assert "different state layout" in msg


def test_elastic_restore_under_new_sharding(tmp_path):
    """Restore with explicit shardings (the elastic-rescale path): arrays
    come back on the requested devices."""
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(3, s)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), s)
    restored, _ = mgr.restore(s, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)
