"""Transformer-scale CHAOS contracts (DESIGN.md §10).

* Chunked layer-stack layouts (``ArchConfig.layer_chunk``) are pure
  re-layouts: ``rechunk_params`` round-trips bit-exactly, and forward
  logits / whole-tree gradients agree across chunkings to float32
  accumulation noise (XLA canonicalises a scan of M chunk bodies
  differently from one whole-stack scan, so bit-identity across LAYOUTS
  is not a contract — bit-identity at a FIXED layout across worker
  schedules is, and rides tests/test_worker_scaling.py).
* Checkpoints written at one chunking restore at another via
  ``rechunk_params`` (CheckpointManager validates leaf shapes, so the
  rechunk is the portability contract).
* ``flash_attention`` with a traced ``q_offset`` (scalar or per-row
  vector) takes the real flash backward — gradients match a dense masked
  reference at the same absolute positions (regression: tracers used to
  fall off the custom VJP onto a forward-only impl, silently zeroing
  cache-offset training gradients).
* ``flash_attention_train`` (the Pallas interpret-mode training forward
  behind ``use_kernel``) matches the jnp blockwise path in forward and
  gradients.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import layers as L
from repro.models import lm

CFG0 = dataclasses.replace(C.get("lm-bench"), n_layers=4, layer_chunk=0)


def _params(cfg, seed=0):
    f = L.InitFactory(jax.random.key(seed), jnp.float32)
    return lm.build_params(cfg, f)


def _batch(cfg, seed=1, B=2, T=32):
    tokens = jax.random.randint(jax.random.key(seed), (B, T), 0,
                                cfg.vocab_size)
    return {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}


# ---------------------------------------------------------------------------
# chunked layer stack == whole stack (float32 accumulation noise only);
# chunk == n_layers is the SAME scan layout and must be bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [1, 2, 4])
def test_chunked_forward_and_grads_agree(chunk):
    base = _params(CFG0)
    batch = _batch(CFG0)
    logits0, _ = jax.jit(lambda p: lm.forward(p, batch["tokens"], CFG0))(base)
    (l0, _), g0 = jax.jit(jax.value_and_grad(
        lambda p: lm.loss_fn(p, batch, CFG0), has_aux=True))(base)

    cfg = dataclasses.replace(CFG0, layer_chunk=chunk)
    params = lm.rechunk_params(base, CFG0, chunk)
    logits, _ = jax.jit(lambda p: lm.forward(p, batch["tokens"], cfg))(params)
    (l, _), g = jax.jit(jax.value_and_grad(
        lambda p: lm.loss_fn(p, batch, cfg), has_aux=True))(params)

    exact = chunk == CFG0.n_layers  # identical ("layers",) scan layout
    if chunk == 1:
        # ISSUE-9 contract: chunk=1 ≡ the UNROLLED layout bit-exact (both
        # run the same python loop of single-layer bodies; the whole-stack
        # scan reassociates, so vs CFG0 it's allclose only — below)
        cfg_unroll = dataclasses.replace(CFG0, scan_layers=False)
        logits_u, _ = jax.jit(
            lambda p: lm.forward(p, batch["tokens"], cfg_unroll))(base)
        np.testing.assert_array_equal(np.asarray(logits_u),
                                      np.asarray(logits))
    if exact:
        np.testing.assert_array_equal(np.asarray(logits0), np.asarray(logits))
        assert float(l0) == float(l)
    else:
        np.testing.assert_allclose(np.asarray(logits0), np.asarray(logits),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(l0), float(l), rtol=1e-6)
    # gradients, re-laid-out back to the whole-stack layout, agree
    g_back = lm.rechunk_params(g, cfg, 0)
    for k in g0:
        for a, b in zip(jax.tree.leaves(g0[k]), jax.tree.leaves(g_back[k])):
            if exact:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=k)
            else:
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-3, atol=1e-5, err_msg=k)


def test_rechunk_roundtrip_identity_and_validation():
    base = _params(CFG0)
    via = lm.rechunk_params(base, CFG0, 2)
    cfg2 = dataclasses.replace(CFG0, layer_chunk=2)
    back = lm.rechunk_params(via, cfg2, 0)
    assert sorted(back) == sorted(base)
    for k in base:
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), base[k], back[k])
    with pytest.raises(ValueError, match="divisor"):
        lm.n_layer_chunks(dataclasses.replace(CFG0, layer_chunk=3))


def test_checkpoint_roundtrip_across_layer_chunk(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    base = _params(CFG0)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, {"params": base, "step": 0})
    restored, _ = mgr.restore({"params": base, "step": 0})
    # restore at the chunked layout: rechunk the restored whole-stack tree
    cfg1 = dataclasses.replace(CFG0, layer_chunk=1)
    chunked = lm.rechunk_params(restored["params"], CFG0, 1)
    template = _params(cfg1, seed=7)  # different seed: shapes only
    assert sorted(chunked) == sorted(template)
    batch = _batch(CFG0)
    logits0, _ = lm.forward(base, batch["tokens"], CFG0)
    logits1, _ = lm.forward(chunked, batch["tokens"], cfg1)
    # cross-LAYOUT forward: float32 accumulation noise only (see module
    # docstring); the rechunk itself is bit-exact (roundtrip test above)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(logits1),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# q_offset gradients ride the real flash backward
# ---------------------------------------------------------------------------
def _ref_attention(q, k, v, q_pos, causal):
    """Dense masked reference at absolute query positions ``q_pos``."""
    B, Tq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, G, D)
    s = jnp.einsum("bthgd,bshd->bhgts", qg, k) / np.sqrt(D)
    if causal:
        kpos = jnp.arange(k.shape[1])
        mask = q_pos[..., None] >= kpos  # (Tq, Tk) or (B, Tq, Tk)
        mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p, v)
    return out.reshape(B, Tq, Hq, D)


@pytest.mark.parametrize("off_form", ["python_int", "traced_scalar",
                                      "traced_vector"])
def test_q_offset_grads_match_dense_reference(off_form):
    B, Tq, Tk, Hq, Hkv, D = 2, 4, 12, 4, 2, 8
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D))
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D))
    if off_form == "python_int":
        off, q_pos = 5, 5 + jnp.arange(Tq)
    elif off_form == "traced_scalar":
        off, q_pos = jnp.asarray(5, jnp.int32), 5 + jnp.arange(Tq)
    else:
        off = jnp.asarray([3, 7], jnp.int32)
        q_pos = off[:, None] + jnp.arange(Tq)

    def loss_flash(q, k, v):
        o = L.flash_attention(q, k, v, causal=True, q_offset=off, block_k=8)
        return (o ** 2).sum()

    def loss_ref(q, k, v):
        return (_ref_attention(q, k, v, q_pos, True) ** 2).sum()

    gf = jax.jit(jax.grad(loss_flash, argnums=(0, 1, 2)))(q, k, v)
    gr = jax.jit(jax.grad(loss_ref, argnums=(0, 1, 2)))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5, err_msg=name)
        assert float(jnp.abs(a).max()) > 0  # regression: not forward-only


# ---------------------------------------------------------------------------
# Pallas training forward (use_kernel) == jnp blockwise path
# ---------------------------------------------------------------------------
def test_flash_attention_train_matches_jnp():
    from repro.kernels.flash_attention import flash_attention_train

    B, T, Hq, Hkv, D = 1, 64, 4, 2, 16
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))

    out_j = L.flash_attention(q, k, v, causal=True)
    out_p = flash_attention_train(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_p),
                               rtol=1e-5, atol=1e-5)

    lj = lambda q, k, v: (L.flash_attention(q, k, v, causal=True) ** 2).mean()
    lp = lambda q, k, v: (flash_attention_train(q, k, v,
                                                causal=True) ** 2).mean()
    gj = jax.jit(jax.grad(lj, argnums=(0, 1, 2)))(q, k, v)
    gp = jax.jit(jax.grad(lp, argnums=(0, 1, 2)))(q, k, v)
    for a, b, name in zip(gj, gp, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


def test_use_kernel_lm_loss_matches_xla_path():
    cfg = C.get("lm-bench")
    params = _params(cfg)
    batch = _batch(cfg, T=64)
    l0, _ = lm.loss_fn(params, batch, cfg)
    cfgk = dataclasses.replace(cfg, use_kernel=True)
    l1, _ = lm.loss_fn(params, batch, cfgk)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
