"""Use real hypothesis when installed; otherwise a minimal deterministic
fallback so ``pytest -x -q`` collects and runs property tests on a clean
machine (no pip installs available in the eval container).

The fallback implements just what this repo's tests use: ``st.integers``,
``st.sampled_from``, ``@given`` (positional or keyword strategies), and
``@settings(max_examples=..., deadline=...)``.  Each wrapped test replays a
fixed number of pseudo-random examples seeded from the test name, so runs
are reproducible; there is no shrinking.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies
except ImportError:
    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 12

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            _DEFAULT_EXAMPLES)
                rng = random.Random(fn.__name__)
                for _ in range(n):
                    pos = [s.sample(rng) for s in pos_strategies]
                    kws = {k: s.sample(rng)
                           for k, s in kw_strategies.items()}
                    fn(*args, *pos, **kws, **kwargs)
            # pytest must not mistake the strategy params for fixtures
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

st = strategies
