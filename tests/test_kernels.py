"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracle (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.kernels import conv2d as K
from repro.kernels import ops as kops
from repro.kernels import ref

# the paper's actual conv layer shapes (Table 2)
PAPER_SHAPES = [
    (8, 29, 29, 1, 4, 5),      # small conv1
    (8, 13, 13, 5, 5, 10),     # small conv2
    (4, 29, 29, 1, 4, 20),     # medium/large conv1
    (4, 13, 13, 20, 5, 40),    # medium conv2
    (2, 26, 26, 20, 5, 60),    # large conv2
    (2, 11, 11, 60, 6, 100),   # large conv3
]


@pytest.mark.parametrize("B,H,W,Cin,Kk,Cout", PAPER_SHAPES)
def test_conv_fwd_paper_shapes(B, H, W, Cin, Kk, Cout):
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (B, H, W, Cin), jnp.float32)
    w = jax.random.normal(k2, (Kk, Kk, Cin, Cout), jnp.float32) * 0.1
    np.testing.assert_allclose(kops.conv2d_valid(x, w),
                               ref.conv2d_valid_ref(x, w),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.key(1))
    x = jax.random.normal(k1, (4, 13, 13, 5), jnp.float32).astype(dtype)
    w = (jax.random.normal(k2, (5, 5, 5, 10), jnp.float32) * 0.1).astype(dtype)
    got = kops.conv2d_valid(x, w).astype(jnp.float32)
    want = ref.conv2d_valid_ref(x.astype(jnp.float32),
                                w.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,W,Cin,Kk,Cout", PAPER_SHAPES[:4])
def test_conv_grads(B, H, W, Cin, Kk, Cout):
    k1, k2 = jax.random.split(jax.random.key(2))
    x = jax.random.normal(k1, (B, H, W, Cin), jnp.float32)
    w = jax.random.normal(k2, (Kk, Kk, Cin, Cout), jnp.float32) * 0.1
    f1 = lambda x, w: jnp.sum(jnp.tanh(kops.conv2d_valid(x, w)))
    f2 = lambda x, w: jnp.sum(jnp.tanh(ref.conv2d_valid_ref(x, w)))
    g1 = jax.grad(f1, (0, 1))(x, w)
    g2 = jax.grad(f2, (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 6),
    H=st.integers(5, 18),
    Cin=st.integers(1, 8),
    Kk=st.integers(1, 5),
    Cout=st.integers(1, 12),
    bb=st.integers(1, 8),
)
def test_conv_fwd_hypothesis(B, H, Cin, Kk, Cout, bb):
    """Property sweep over arbitrary shapes and batch blockings."""
    if Kk > H:
        return
    k1, k2 = jax.random.split(jax.random.key(B * 1000 + H))
    x = jax.random.normal(k1, (B, H, H, Cin), jnp.float32)
    w = jax.random.normal(k2, (Kk, Kk, Cin, Cout), jnp.float32) * 0.2
    got = K.conv2d_fwd(x, w, batch_block=bb, interpret=True)
    np.testing.assert_allclose(got, ref.conv2d_valid_ref(x, w),
                               atol=2e-4, rtol=2e-4)


def test_dw_kernel_matches_ref():
    k1, k2 = jax.random.split(jax.random.key(3))
    x = jax.random.normal(k1, (6, 13, 13, 5), jnp.float32)
    dy = jax.random.normal(k2, (6, 9, 9, 10), jnp.float32)
    got = K.conv2d_dw(x, dy, (5, 5, 5, 10), interpret=True)
    np.testing.assert_allclose(got, ref.conv2d_dw_ref(x, dy),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# Tiled + fused + autotuned conv pipeline (DESIGN.md §Kernels)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bb", [2, 4, 8])
def test_dw_cross_step_accumulation_regression(bb):
    """_conv_dw_kernel accumulates across grid steps via sequential-grid
    revisiting of its fp32 scratch: with batch_block < B the result must
    still equal the whole-batch XLA reference (interpret path here; the
    non-interpret path runs in test_dw_accumulation_compiled on TPU)."""
    k1, k2 = jax.random.split(jax.random.key(11))
    x = jax.random.normal(k1, (8, 13, 13, 5), jnp.float32)
    dy = jax.random.normal(k2, (8, 9, 9, 10), jnp.float32)
    got = K.conv2d_dw(x, dy, (5, 5, 5, 10), batch_block=bb, interpret=True)
    np.testing.assert_allclose(got, ref.conv2d_dw_ref(x, dy),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="non-interpret Pallas needs a TPU backend")
def test_dw_accumulation_compiled():
    """Same regression through the compiled (non-interpret) path."""
    k1, k2 = jax.random.split(jax.random.key(11))
    x = jax.random.normal(k1, (8, 13, 13, 5), jnp.float32)
    dy = jax.random.normal(k2, (8, 9, 9, 10), jnp.float32)
    got = K.conv2d_dw(x, dy, (5, 5, 5, 10), batch_block=2, interpret=False)
    np.testing.assert_allclose(got, ref.conv2d_dw_ref(x, dy),
                               atol=1e-3, rtol=1e-3)


def test_conv_fwd_row_block_tiling_large_map():
    """64x64 feature map — larger than a single whole-image VMEM block at
    production channel counts — streamed through in halo'd row slabs."""
    k1, k2 = jax.random.split(jax.random.key(21))
    x = jax.random.normal(k1, (2, 64, 64, 3), jnp.float32)
    w = jax.random.normal(k2, (5, 5, 3, 8), jnp.float32) * 0.1
    want = ref.conv2d_valid_ref(x, w)
    for rb, cb in [(15, None), (20, 4), (12, 8), (4, None)]:
        got = K.conv2d_fwd(x, w, batch_block=1, row_block=rb, cout_block=cb,
                           interpret=True)
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4,
                                   err_msg=f"row_block={rb} cout_block={cb}")


def test_conv_bwd_fused_row_block_tiling_large_map():
    k1, k2, k3 = jax.random.split(jax.random.key(22), 3)
    x = jax.random.normal(k1, (2, 64, 64, 3), jnp.float32)
    w = jax.random.normal(k2, (5, 5, 3, 8), jnp.float32) * 0.1
    dy = jax.random.normal(k3, (2, 60, 60, 8), jnp.float32)
    f = lambda x, w: jnp.sum(ref.conv2d_valid_ref(x, w) * dy)
    gx, gw = jax.grad(f, (0, 1))(x, w)
    for rb in (16, 8):
        dx, dw, db = K.conv2d_bwd_fused(x, dy, w, batch_block=2,
                                        row_block=rb, interpret=True)
        np.testing.assert_allclose(dx, gx, atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(dw, gw, atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(db, jnp.sum(dy, (0, 1, 2)),
                                   atol=2e-3, rtol=2e-3)


def test_conv_fused_epilogue_fwd():
    """conv + bias + tanh in one launch == XLA composition."""
    k1, k2, k3 = jax.random.split(jax.random.key(23), 3)
    x = jax.random.normal(k1, (4, 29, 29, 1), jnp.float32)
    w = jax.random.normal(k2, (4, 4, 1, 5), jnp.float32) * 0.2
    b = jax.random.normal(k3, (5,), jnp.float32) * 0.1
    got = K.conv2d_fwd(x, w, b, activation="tanh", row_block=13,
                       interpret=True)
    np.testing.assert_allclose(got, jnp.tanh(ref.conv2d_valid_ref(x, w) + b),
                               atol=1e-4, rtol=1e-4)


# two Table-2 layer shapes for the end-to-end gradient acceptance check
GRAD_E2E_SHAPES = [
    (8, 29, 29, 1, 4, 5),      # small conv1
    (4, 13, 13, 20, 5, 40),    # medium conv2
]


@pytest.mark.parametrize("B,H,W,Cin,Kk,Cout", GRAD_E2E_SHAPES)
def test_grad_e2e_custom_vjp_vs_xla(B, H, W, Cin, Kk, Cout):
    """jax.grad through the kops.conv2d_valid custom VJP (fused Pallas
    backward) must match jax.grad through lax.conv_general_dilated."""
    k1, k2 = jax.random.split(jax.random.key(31))
    x = jax.random.normal(k1, (B, H, W, Cin), jnp.float32)
    w = jax.random.normal(k2, (Kk, Kk, Cin, Cout), jnp.float32) * 0.1
    f1 = lambda x, w: jnp.sum(jnp.cos(kops.conv2d_valid(x, w)))
    f2 = lambda x, w: jnp.sum(jnp.cos(ref.conv2d_valid_ref(x, w)))
    g1 = jax.grad(f1, (0, 1))(x, w)
    g2 = jax.grad(f2, (0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,H,W,Cin,Kk,Cout", GRAD_E2E_SHAPES)
def test_grad_e2e_fused_epilogue_vs_xla(B, H, W, Cin, Kk, Cout):
    """Same check for the fused conv+bias+tanh variant (dtanh folded into
    the single backward launch), including the bias gradient."""
    k1, k2, k3 = jax.random.split(jax.random.key(32), 3)
    x = jax.random.normal(k1, (B, H, W, Cin), jnp.float32)
    w = jax.random.normal(k2, (Kk, Kk, Cin, Cout), jnp.float32) * 0.1
    b = jax.random.normal(k3, (Cout,), jnp.float32) * 0.1
    f1 = lambda x, w, b: jnp.sum(kops.conv2d_bias_tanh(x, w, b))
    f2 = lambda x, w, b: jnp.sum(jnp.tanh(ref.conv2d_valid_ref(x, w) + b))
    g1 = jax.grad(f1, (0, 1, 2))(x, w, b)
    g2 = jax.grad(f2, (0, 1, 2))(x, w, b)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4)


def test_fused_epilogue_mixed_precision_bias_grad():
    """bf16 activations with an fp32 bias (standard mixed-precision layout):
    the custom VJP must return db in the bias's own dtype."""
    k1, k2, k3 = jax.random.split(jax.random.key(33), 3)
    x = jax.random.normal(k1, (4, 13, 13, 5), jnp.float32).astype(
        jnp.bfloat16)
    w = (jax.random.normal(k2, (5, 5, 5, 10), jnp.float32) * 0.1).astype(
        jnp.bfloat16)
    b = jax.random.normal(k3, (10,), jnp.float32) * 0.1
    grads = jax.grad(lambda x, w, b: jnp.sum(
        kops.conv2d_bias_tanh(x, w, b).astype(jnp.float32)), (0, 1, 2))(
        x, w, b)
    assert grads[0].dtype == jnp.bfloat16
    assert grads[1].dtype == jnp.bfloat16
    assert grads[2].dtype == jnp.float32


def test_conv_launch_count_per_train_step():
    """The fusion acceptance criterion: with use_kernel=True, each conv
    layer of a train step issues exactly 2 Pallas launches (one fused
    forward, one fused backward) — down from 3 (fwd + dx + dw)."""
    import repro.configs as C
    from repro.models import cnn
    from repro.models import layers as L
    cfg = C.get("chaos-small")
    params = cnn.build_params(cfg, L.InitFactory(jax.random.key(0),
                                                 jnp.float32))
    batch = {"images": jax.random.uniform(jax.random.key(1), (4, 29, 29, 1)),
             "labels": jax.random.randint(jax.random.key(2), (4,), 0, 10)}
    n_conv = sum(1 for s in cfg.cnn_layers if s[0] == "conv")
    with K.launch_trace() as rec:
        jax.grad(lambda p: cnn.loss_fn(p, batch, cfg, use_kernel=True)[0])(
            params)
    assert rec.count("conv2d_fwd") == n_conv
    assert rec.count("conv2d_bwd_fused") == n_conv
    conv_launches = [r for r in rec if r.startswith("conv2d")]
    assert len(conv_launches) == 2 * n_conv, conv_launches


def test_cnn_kernel_grads_match_xla_path():
    """Full train-step gradients via the fused Pallas path == via XLA."""
    import repro.configs as C
    from repro.models import cnn
    from repro.models import layers as L
    cfg = C.get("chaos-small")
    params = cnn.build_params(cfg, L.InitFactory(jax.random.key(0),
                                                 jnp.float32))
    batch = {"images": jax.random.uniform(jax.random.key(1), (4, 29, 29, 1)),
             "labels": jax.random.randint(jax.random.key(2), (4,), 0, 10)}
    g1 = jax.grad(lambda p: cnn.loss_fn(p, batch, cfg, use_kernel=True)[0])(
        params)
    g2 = jax.grad(lambda p: cnn.loss_fn(p, batch, cfg, use_kernel=False)[0])(
        params)
    flat1, _ = jax.tree_util.tree_flatten(g1)
    flat2, _ = jax.tree_util.tree_flatten(g2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-3)


def test_maxpool_kernel_matches_xla():
    x = jax.random.normal(jax.random.key(41), (4, 29, 29, 5), jnp.float32)
    for k in (2, 3):
        got = kops.maxpool2d(x, k)
        want = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                     (1, k, k, 1), (1, k, k, 1), "VALID")
        np.testing.assert_allclose(got, want)
        g1 = jax.grad(lambda x: jnp.sum(jnp.sin(kops.maxpool2d(x, k))))(x)
        g2 = jax.grad(lambda x: jnp.sum(jnp.sin(jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1),
            "VALID"))))(x)
        np.testing.assert_allclose(g1, g2, atol=1e-5)


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """tune_conv_fwd persists to the JSON cache, survives a memory-cache
    clear, and never picks a config slower than the batch_block=8
    baseline on its own measurements."""
    from repro.kernels import autotune as AT
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    AT.clear_memory_cache()
    k1, k2 = jax.random.split(jax.random.key(51))
    x = jax.random.normal(k1, (8, 13, 13, 5), jnp.float32)
    w = jax.random.normal(k2, (5, 5, 5, 10), jnp.float32) * 0.1
    cfg, rep = AT.tune_conv_fwd(x, w, iters=1)
    assert rep["best_us"] <= rep["baseline_us"]
    AT.clear_memory_cache()
    entry = AT.lookup(rep["key"])
    assert entry is not None and entry["config"] == cfg
    # the tuned config must be numerically identical to the baseline
    got = K.conv2d_fwd(x, w, interpret=True, **cfg)
    np.testing.assert_allclose(got, ref.conv2d_valid_ref(x, w),
                               atol=1e-4, rtol=1e-4)
    AT.clear_memory_cache()


def test_autotune_candidates_respect_vmem_budget():
    from repro.kernels import autotune as AT
    x_shape, w_shape = (8, 64, 64, 32), (5, 5, 32, 128)
    cands = AT.conv_fwd_candidates(x_shape, w_shape)
    assert dict(AT.BASELINE) in cands   # baseline always measured
    for cfg in cands[1:]:
        assert AT.conv_fwd_vmem_bytes(cfg, x_shape, w_shape) <= \
            AT.VMEM_BUDGET_BYTES


def test_cnn_with_kernel_matches_xla_path():
    """End-to-end: the paper CNN forward via Pallas == via XLA conv."""
    import repro.configs as C
    from repro.models import cnn
    from repro.models import layers as L
    cfg = C.get("chaos-small")
    params = cnn.build_params(cfg, L.InitFactory(jax.random.key(0),
                                                 jnp.float32))
    x = jax.random.uniform(jax.random.key(1), (4, 29, 29, 1))
    y1 = cnn.forward(params, x, cfg, use_kernel=False)
    y2 = cnn.forward(params, x, cfg, use_kernel=True)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Pallas flash-attention kernel (the §Perf memory-term optimization)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,Hq,Hkv,T,D,Dv,causal,bq,bk", [
    (1, 2, 2, 128, 32, 32, True, 32, 32),
    (2, 4, 2, 96, 16, 16, True, 32, 32),      # GQA + non-dividing T
    (1, 2, 1, 256, 64, 32, False, 64, 128),   # Dv != D, non-causal
    (1, 1, 1, 70, 16, 16, True, 32, 32),      # ragged tail
])
def test_pallas_flash_attention(B, Hq, Hkv, T, D, Dv, causal, bq, bk):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models import layers as L
    ks = jax.random.split(jax.random.key(B * 7 + T), 3)
    q = jax.random.normal(ks[0], (B, Hq, T, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, T, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, T, Dv), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, block_q=bq, block_k=bk)
    # oracle: the validated jnp blockwise implementation (BTHD layout)
    want = L.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal
                             ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_attention_dtypes(dtype):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models import layers as L
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.float32).astype(dtype)
    got = flash_attention_fwd(q, k, v, causal=True, block_q=32, block_k=32)
    want = L.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True
                             ).transpose(0, 2, 1, 3)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# Pallas WKV6 recurrence kernel (attention-free archs' hot spot)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,H,D,chunk", [
    (2, 192, 3, 16, 64),
    (1, 64, 2, 32, 32),
    (2, 256, 1, 64, 64),   # production tile shape (D=64)
])
def test_pallas_wkv6_kernel(B, T, H, D, chunk):
    from repro.kernels.wkv6 import wkv6_chunked
    from repro.models.rwkv6 import wkv_chunked
    ks = jax.random.split(jax.random.key(B * 13 + T), 5)
    r = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D))
    w = jnp.exp(-jnp.exp(jnp.clip(
        jax.random.normal(ks[3], (B, T, H, D)), None, 0.0)))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    got = wkv6_chunked(r, k, v, w, u, chunk=chunk)
    want, _ = wkv_chunked(r, k, v, w, u)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_pallas_wkv6_state_continuity():
    """The VMEM-carried state must make chunk boundaries seamless: kernel
    output == naive per-token recurrence across many chunks."""
    from repro.kernels.wkv6 import wkv6_chunked
    from tests.test_numerics import naive_wkv
    B, T, H, D = 1, 128, 2, 8
    ks = jax.random.split(jax.random.key(77), 5)
    r = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D))
    w = jnp.exp(-jnp.exp(jnp.clip(
        jax.random.normal(ks[3], (B, T, H, D)), None, 0.0)))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    got = wkv6_chunked(r, k, v, w, u, chunk=32)
    want, _ = naive_wkv(r, k, v, w, u)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# q_offset: absolute query position in the flash kernel's causal mask (§9).
# Pre-fix, the kernel assumed q and k both start at position 0, so a batched
# prefill of a CONTINUED sequence (queries at cache positions
# [cache_len, cache_len+Tq)) masked every cached key as "future".
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("offset_kind", ["zero", "cache_len"])
def test_pallas_flash_attention_q_offset(offset_kind):
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.models import layers as L
    B, Hq, Hkv, Tq, Tk, D = 2, 4, 2, 16, 64, 32
    offset = 0 if offset_kind == "zero" else Tk - Tq   # append at cache tail
    ks = jax.random.split(jax.random.key(41), 3)
    q = jax.random.normal(ks[0], (B, Hq, Tq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, Tk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, Tk, D), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=True, q_offset=offset,
                              block_q=16, block_k=32)
    want = L.flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True,
                             q_offset=offset).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)
    if offset:
        # regression vs the pre-fix behaviour: offset must actually admit
        # the cached keys, i.e. differ from running the kernel at offset 0
        at0 = flash_attention_fwd(q, k, v, causal=True, q_offset=0,
                                  block_q=16, block_k=32)
        assert not np.allclose(np.asarray(got), np.asarray(at0))


def test_pallas_flash_attention_q_offset_traced():
    """A traced (jitted scalar) offset must match the python-int program —
    the offset rides in SMEM, so one compiled program serves every cache
    position."""
    from repro.kernels.flash_attention import flash_attention_fwd
    B, H, Tq, Tk, D = 1, 2, 8, 32, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, H, Tq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, Tk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, Tk, D), jnp.float32)
    fn = jax.jit(lambda off: flash_attention_fwd(
        q, k, v, causal=True, q_offset=off, block_q=8, block_k=16))
    for off in (0, 13, Tk - Tq):
        np.testing.assert_allclose(
            np.asarray(fn(jnp.int32(off))),
            np.asarray(flash_attention_fwd(q, k, v, causal=True,
                                           q_offset=off, block_q=8,
                                           block_k=16)),
            atol=1e-6, rtol=1e-6)
