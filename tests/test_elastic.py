"""Elastic re-meshing integration: train on N devices, checkpoint, resume
on a DIFFERENT device count, and verify the loss sequence continues as if
nothing happened (global batch is device-count-independent)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(n_dev: int, code: str):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_resume_on_different_device_count(tmp_path):
    common = """
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.core.chaos import SyncConfig
        from repro.data.pipeline import TokenPipeline
        from repro.train.step import init_train_state, make_optimizer, make_train_step
        from repro.train import sharding as SH
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.elastic import resume_elastic, make_mesh_from_available
        import dataclasses
        cfg = dataclasses.replace(C.smoke("qwen3-14b"), param_dtype="float32")
        sync = SyncConfig("bsp")
        pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=32)
    """

    # phase 1: train 6 steps on 2 devices, checkpoint
    _run(2, common + f"""
        from repro.optim import sgd
        opt = sgd(lambda s: 0.01)
        mesh = make_mesh_from_available((2,), ("data",))
        from repro.train.step import state_specs
        with SH.use_mesh(mesh):
            state = init_train_state(cfg, jax.random.key(0), sync, opt)
            specs = state_specs(cfg, sync, opt)
            sh = SH.shardings_for(specs, state, mesh)
            step = jax.jit(make_train_step(cfg, sync, opt),
                           in_shardings=(sh, None), out_shardings=(sh, None))
            losses = []
            for t in range(6):
                state, m = step(state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(6, state)
        print("PHASE1", losses)
    """)

    # phase 2: resume on 4 devices; the next losses must continue the run
    out = _run(4, common + f"""
        from repro.optim import sgd
        opt = sgd(lambda s: 0.01)
        state, start, mesh, step = resume_elastic(
            cfg, sync, r"{tmp_path}", mesh_shape=(4,), axes=("data",),
            optimizer=opt)
        assert start == 6
        assert mesh.devices.size == 4
        losses = []
        for t in range(start, start + 3):
            state, m = step(state, pipe.batch_at(t))
            losses.append(float(m["loss"]))
        print("PHASE2", losses)
    """)
    assert "PHASE2" in out

    # phase 3: reference — uninterrupted 9 steps on 2 devices
    ref = _run(2, common + f"""
        from repro.optim import sgd
        opt = sgd(lambda s: 0.01)
        mesh = make_mesh_from_available((2,), ("data",))
        from repro.train.step import state_specs
        with SH.use_mesh(mesh):
            state = init_train_state(cfg, jax.random.key(0), sync, opt)
            specs = state_specs(cfg, sync, opt)
            sh = SH.shardings_for(specs, state, mesh)
            step = jax.jit(make_train_step(cfg, sync, opt),
                           in_shardings=(sh, None), out_shardings=(sh, None))
            losses = []
            for t in range(9):
                state, m = step(state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        print("REF", losses[6:])
    """)
    import ast
    got = ast.literal_eval(out.split("PHASE2")[1].strip().splitlines()[0])
    want = ast.literal_eval(ref.split("REF")[1].strip().splitlines()[0])
    import numpy as np
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
