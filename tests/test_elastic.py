"""Elastic re-meshing integration: train on N devices, checkpoint, resume
on a DIFFERENT device count, and verify the loss sequence continues as if
nothing happened (global batch is device-count-independent)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(n_dev: int, code: str):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_resume_on_different_device_count(tmp_path):
    common = """
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.core.chaos import SyncConfig
        from repro.data.pipeline import TokenPipeline
        from repro.train.step import init_train_state, make_optimizer, make_train_step
        from repro.train import sharding as SH
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.elastic import resume_elastic, make_mesh_from_available
        import dataclasses
        cfg = dataclasses.replace(C.smoke("qwen3-14b"), param_dtype="float32")
        sync = SyncConfig("bsp")
        pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=32)
    """

    # phase 1: train 6 steps on 2 devices, checkpoint
    _run(2, common + f"""
        from repro.optim import sgd
        opt = sgd(lambda s: 0.01)
        mesh = make_mesh_from_available((2,), ("data",))
        from repro.train.step import state_specs
        with SH.use_mesh(mesh):
            state = init_train_state(cfg, jax.random.key(0), sync, opt)
            specs = state_specs(cfg, sync, opt)
            sh = SH.shardings_for(specs, state, mesh)
            step = jax.jit(make_train_step(cfg, sync, opt),
                           in_shardings=(sh, None), out_shardings=(sh, None))
            losses = []
            for t in range(6):
                state, m = step(state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(6, state)
        print("PHASE1", losses)
    """)

    # phase 2: resume on 4 devices; the next losses must continue the run
    out = _run(4, common + f"""
        from repro.optim import sgd
        opt = sgd(lambda s: 0.01)
        state, start, mesh, step = resume_elastic(
            cfg, sync, r"{tmp_path}", mesh_shape=(4,), axes=("data",),
            optimizer=opt)
        assert start == 6
        assert mesh.devices.size == 4
        losses = []
        for t in range(start, start + 3):
            state, m = step(state, pipe.batch_at(t))
            losses.append(float(m["loss"]))
        print("PHASE2", losses)
    """)
    assert "PHASE2" in out

    # phase 3: reference — uninterrupted 9 steps on 2 devices
    ref = _run(2, common + f"""
        from repro.optim import sgd
        opt = sgd(lambda s: 0.01)
        mesh = make_mesh_from_available((2,), ("data",))
        from repro.train.step import state_specs
        with SH.use_mesh(mesh):
            state = init_train_state(cfg, jax.random.key(0), sync, opt)
            specs = state_specs(cfg, sync, opt)
            sh = SH.shardings_for(specs, state, mesh)
            step = jax.jit(make_train_step(cfg, sync, opt),
                           in_shardings=(sh, None), out_shardings=(sh, None))
            losses = []
            for t in range(9):
                state, m = step(state, pipe.batch_at(t))
                losses.append(float(m["loss"]))
        print("REF", losses[6:])
    """)
    import ast
    got = ast.literal_eval(out.split("PHASE2")[1].strip().splitlines()[0])
    want = ast.literal_eval(ref.split("REF")[1].strip().splitlines()[0])
    import numpy as np
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_make_mesh_from_available_over_ask_is_actionable():
    """Satellite bugfix: a mesh_shape needing more devices than are visible
    must raise naming BOTH counts and the XLA_FLAGS remedy — not crash
    inside jax.make_mesh with an opaque shape error."""
    import pytest
    from repro.launch.elastic import make_mesh_from_available
    with pytest.raises(ValueError) as ei:
        make_mesh_from_available((64, 2))
    msg = str(ei.value)
    assert "128 device(s)" in msg
    assert "xla_force_host_platform_device_count=128" in msg


def test_resume_on_non_dividing_device_count(tmp_path):
    """Resume a 2-device run on 3 devices — a count that divides neither
    the old mesh nor the batch axis cleanly; shardings_for's per-dim
    divisibility fallback must still produce a working step whose losses
    continue the run (same global batch)."""
    common = """
        import jax, numpy as np, dataclasses
        import repro.configs as C
        from repro.core.chaos import SyncConfig
        from repro.data.pipeline import TokenPipeline
        from repro.train.step import (init_train_state, make_train_step,
                                      state_specs)
        from repro.train import sharding as SH
        from repro.checkpoint.manager import CheckpointManager
        from repro.launch.elastic import resume_elastic, make_mesh_from_available
        from repro.optim import sgd
        cfg = dataclasses.replace(C.smoke("qwen3-14b"), param_dtype="float32")
        sync = SyncConfig("bsp")
        pipe = TokenPipeline(cfg.vocab_size, batch=8, seq_len=32)
        opt = sgd(lambda s: 0.01)
    """
    _run(2, common + f"""
        mesh = make_mesh_from_available((2,), ("data",))
        with SH.use_mesh(mesh):
            state = init_train_state(cfg, jax.random.key(0), sync, opt)
            sh = SH.shardings_for(state_specs(cfg, sync, opt), state, mesh)
            step = jax.jit(make_train_step(cfg, sync, opt),
                           in_shardings=(sh, None), out_shardings=(sh, None))
            for t in range(4):
                state, m = step(state, pipe.batch_at(t))
        CheckpointManager(r"{tmp_path}").save(4, state)
        print("SAVED", float(m["loss"]))
    """)
    out = _run(3, common + f"""
        state, start, mesh, step = resume_elastic(
            cfg, sync, r"{tmp_path}", mesh_shape=(3,), axes=("data",),
            optimizer=opt)
        assert start == 4 and mesh.devices.size == 3
        losses = []
        for t in range(start, start + 2):
            state, m = step(state, pipe.batch_at(t))
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        print("RESUMED", losses)
    """)
    assert "RESUMED" in out


def test_localsgd_stacked_checkpoint_across_worker_counts(tmp_path):
    """Worker-stacked (N, ...) localsgd checkpoints pin N: restoring into
    an N'=2 template must FAIL the shape check, and the supported route —
    restore at the old N, then ``resize_worker_state`` — must apply the
    documented group-mean rule (defined-but-different, pinned here leaf by
    leaf against a numpy reference)."""
    _run(4, f"""
        import jax, numpy as np
        import repro.configs as C
        from repro.core.chaos import SyncConfig
        from repro.core.types import WorkerConfig
        from repro.data.mnist import make_dataset
        from repro.data.pipeline import ImagePipeline
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import put_worker_sharded
        from repro.train.step import (init_worker_state, make_optimizer,
                                      make_worker_superstep,
                                      resize_worker_state)
        from repro.checkpoint.manager import CheckpointManager

        cfg = C.get("chaos-small")
        imgs, labels = make_dataset(64, seed=0)
        pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")
        worker = WorkerConfig(workers=4, logical_shards=8)
        mesh = make_host_mesh(4)
        sync = SyncConfig("localsgd", local_steps=2, axis_name=worker.axis)
        opt = make_optimizer(cfg, total_steps=8)
        fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
        state = init_worker_state(cfg, jax.random.key(0), sync, worker, opt)
        # odd step count: workers hold genuinely diverged local params
        state, _ = fn(state, put_worker_sharded(pipe, 0, 3, mesh, worker))
        CheckpointManager(r"{tmp_path}").save(3, state)
        print("SAVED4")
    """)
    out = _run(2, f"""
        import jax, numpy as np
        import repro.configs as C
        from repro.core.chaos import SyncConfig
        from repro.core.types import WorkerConfig
        from repro.train.step import (init_worker_state, make_optimizer,
                                      resize_worker_state)
        from repro.checkpoint.manager import CheckpointManager

        cfg = C.get("chaos-small")
        sync = SyncConfig("localsgd", local_steps=2, axis_name="workers")
        opt = make_optimizer(cfg, total_steps=8)
        mgr = CheckpointManager(r"{tmp_path}")

        # restoring a 4-stacked checkpoint into a 2-stacked template fails
        # the shape check with the worker-count diagnosis
        t2 = init_worker_state(cfg, jax.random.key(0), sync,
                               WorkerConfig(2, logical_shards=8), opt)
        try:
            mgr.restore(t2)
            raise SystemExit("shape check did not fire")
        except ValueError as e:
            assert "worker-stacked" in str(e), e

        # supported route: restore at the WRITTEN N, then re-slot 4 -> 2
        t4 = init_worker_state(cfg, jax.random.key(0), sync,
                               WorkerConfig(4, logical_shards=8), opt)
        state4, step = mgr.restore(t4)
        assert step == 3
        state2 = resize_worker_state(state4, sync,
                                     WorkerConfig(4, logical_shards=8),
                                     WorkerConfig(2, logical_shards=8))
        for k in ("params", "opt", "step"):
            for a4, a2 in zip(jax.tree.leaves(state4[k]),
                              jax.tree.leaves(state2[k])):
                a4 = np.asarray(a4); a2 = np.asarray(a2)
                assert a2.shape == (2,) + a4.shape[1:], (a4.shape, a2.shape)
                want = a4.astype(np.float32).reshape(
                    (2, 2) + a4.shape[1:]).mean(axis=1).astype(a4.dtype)
                np.testing.assert_array_equal(a2, want)
        print("RESLOTTED")
    """)
    assert "RESLOTTED" in out
