"""Forward parity of the Pallas WKV6 kernel against the pure-jnp chunked
oracle (``models/rwkv6.wkv_chunked``), with a skip guard for hosts whose
jax build lacks a working interpret-mode Pallas."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _pallas_available():
    try:
        from repro.kernels import ops as kops
        jax.block_until_ready(kops.conv2d_valid(
            jnp.zeros((1, 6, 6, 1), jnp.float32),
            jnp.zeros((3, 3, 1, 2), jnp.float32)))
        return True
    except Exception:  # noqa: BLE001 — any failure means "skip"
        return False


pytestmark = pytest.mark.skipif(
    not _pallas_available(),
    reason="interpret-mode Pallas unavailable on this host")


def _inputs(key, B, T, H, D):
    ks = jax.random.split(jax.random.key(key), 5)
    r = jax.random.normal(ks[0], (B, T, H, D)) * 0.5
    k = jax.random.normal(ks[1], (B, T, H, D)) * 0.5
    v = jax.random.normal(ks[2], (B, T, H, D))
    # the model's decay parameterisation: dec clamped <= 0, w = exp(-exp(dec))
    # lands w in (0, 1] — exactly what the kernel's log-space carry assumes
    dec = jnp.clip(jax.random.normal(ks[3], (B, T, H, D)), None, 0.0)
    w = jnp.exp(-jnp.exp(dec))
    u = jax.random.normal(ks[4], (H, D)) * 0.1
    return r, k, v, w, u


@pytest.mark.parametrize("B,T,H,D,chunk", [
    (2, 128, 2, 16, 64),     # multi-chunk, multi-batch
    (1, 64, 4, 8, 64),       # single chunk exactly
    (2, 256, 2, 32, 32),     # many small chunks
])
def test_wkv6_fwd_parity(B, T, H, D, chunk):
    from repro.kernels.wkv6 import wkv6_chunked
    from repro.models.rwkv6 import wkv_chunked
    r, k, v, w, u = _inputs(B * 1000 + T, B, T, H, D)
    got = wkv6_chunked(r, k, v, w, u, chunk=chunk)
    want, _ = wkv_chunked(r, k, v, w, u)
    assert got.shape == want.shape == (B, T, H, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)


def test_wkv6_fwd_parity_under_jit():
    """The kernel must trace cleanly inside jit with the oracle's exact
    input distribution (the serve/train paths always call it jitted)."""
    from repro.kernels.wkv6 import wkv6_chunked
    from repro.models.rwkv6 import wkv_chunked
    B, T, H, D = 1, 128, 2, 16
    r, k, v, w, u = _inputs(7, B, T, H, D)
    got = jax.jit(lambda *a: wkv6_chunked(*a, chunk=64))(r, k, v, w, u)
    want, _ = wkv_chunked(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-3)
