"""Regression tests for the paper's performance model (Section 5.2) —
this is the quantitative reproduction of Tables 8/9 and Result 3."""
import numpy as np
import pytest

from repro.core import perf_model as pm


def test_table8_small_large_within_1pct():
    """Small & large CNN predictions reproduce the paper's Table 8 to <1%."""
    t8 = pm.table8()
    for arch in ("small", "large"):
        for p, ref in pm.PAPER_TABLE8[arch].items():
            assert abs(t8[arch][p] - ref) / ref < 0.01, (arch, p, t8[arch][p])


def test_table8_medium_within_paper_deviation():
    """Medium matches within the paper's own reported model deviation
    (14.76% average for medium) + margin."""
    t8 = pm.table8()
    for p, ref in pm.PAPER_TABLE8["medium"].items():
        assert abs(t8["medium"][p] - ref) / ref < 0.15, (p, t8["medium"][p])


def test_table9_doubling_epochs_doubles_time():
    """Paper Table 9: doubling images or epochs ~doubles execution time;
    doubling threads does NOT halve it."""
    base = pm.predict_time("small", 240)
    assert abs(base / 60 - 8.9) / 8.9 < 0.02
    t2ep = pm.predict_time("small", 240, ep=140)
    assert 1.9 < t2ep / base < 2.05
    t2im = pm.predict_time("small", 240, i=120_000, it=20_000)
    assert 1.9 < t2im / base < 2.05
    t2p = pm.predict_time("small", 480)
    assert t2p / base > 0.6  # far from the 0.5 of perfect scaling


def test_result3_speedups():
    """Result 3: ~103x vs 1 Phi thread (large CNN conv layers; the overall
    model gives ~100x for large), and graceful small-arch scaling."""
    s_large = pm.predict_speedup("large", 244)
    assert 85 <= s_large <= 110, s_large
    s_small = pm.predict_speedup("small", 244)
    assert 55 <= s_small <= 75, s_small
    # near-linear to 60 threads (Fig 8): doubling 15->30->60.  The small
    # CNN's sequential floor + memory contention bite earlier in the model
    # (the paper's measured small-arch curve also flattens first).
    lo = {"small": 1.6, "medium": 1.8, "large": 1.8}
    for arch in ("small", "medium", "large"):
        t15 = pm.predict_time(arch, 15)
        t30 = pm.predict_time(arch, 30)
        t60 = pm.predict_time(arch, 60)
        assert lo[arch] < t15 / t30 < 2.1, (arch, t15 / t30)
        assert lo[arch] < t30 / t60 < 2.1, (arch, t30 / t60)


def test_scaling_beyond_hw_threads_monotone_with_diminishing_returns():
    """Result 6: CHAOS scales to thousands of threads, with diminishing
    returns (Table 8's flattening curve)."""
    for arch in ("small", "medium", "large"):
        ts = [pm.predict_time(arch, p) for p in (480, 960, 1920, 3840)]
        assert all(a > b for a, b in zip(ts, ts[1:])), ts  # monotone faster
        gain1 = ts[0] / ts[1]
        gain3 = ts[2] / ts[3]
        assert gain3 < gain1  # flattening


def test_memory_contention_extrapolation_matches_paper_predicted_rows():
    for arch in ("small", "medium", "large"):
        for p in (480, 960, 1920, 3840):
            ref = pm.MEM_CONTENTION[arch][p]
            est = pm.memory_contention(arch, p * 1)  # exact-row lookup
            assert est == ref
    # linear extrapolation between anchor rows
    est = pm.memory_contention("small", 2400)
    assert abs(est - pm.MEM_CONTENTION["small"][240] * 10) / est < 1e-6


def test_cpi_rule():
    assert pm.cpi(60) == 1.0
    assert pm.cpi(122) == 1.0    # 2 threads/core
    assert pm.cpi(180) == 1.5    # 3 threads/core
    assert pm.cpi(240) == 2.0
    assert pm.cpi(3840) == 2.0
