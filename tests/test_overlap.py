"""Overlap & collective-latency harness pins (DESIGN.md §8).

The contracts:
  * the deadline-based delay injection is VALUE-neutral and deterministic:
    with ``collective_delay_ns_per_byte`` > 0 the trained state and logged
    losses are bit-identical run-to-run, and for the τ-ring bit-identical
    to the delay-off run (the gates add 0.0 and where-select ties only);
  * the interleaved bucket schedule (``SyncConfig.interleave``) trains the
    same model as collect-then-walk: losses/params agree to float tolerance
    (NOT bit-exact — the per-layer tape changes XLA:CPU canonical forms by
    ~1 ulp, which is why interleave is opt-in and the layerwise bit-exact
    pins ride the collect schedule);
  * τ-ring localsgd: τ=0 IS the blocking boundary pmean (worker-identical
    params equal to the pre-boundary worker mean, bit-exact); τ>=1 shifts
    the correction τ boundaries into the future — before the first
    correction lands the trajectory is bit-equal to a never-averaging run,
    the ring holds exactly ``pmean(params) - params``, and corrections
    preserve the cross-worker mean;
  * layerwise composes with ``cfg.micro_batches > 1`` via the
    bucket-granular accumulator, bit-exact to the batched micro-batch
    update for bsp+SGD;
  * the injected charge matches the roofline collective model: measured
    blocking exchange cost tracks ``parse_collectives(HLO).effective_bytes
    × delay`` at two delay settings.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_sub(code: str, n_dev: int = 4):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


_SETUP = """
    import jax, jax.numpy as jnp, numpy as np
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.core.types import WorkerConfig
    from repro.data.mnist import make_dataset
    from repro.data.pipeline import ImagePipeline
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import put_worker_sharded
    from repro.train.step import (init_worker_state, make_optimizer,
                                  make_worker_superstep)

    cfg = C.get("chaos-small")
    imgs, labels = make_dataset(128, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")

    def run(n, mode, tau=1, steps=4, K=2, layerwise=False, local_steps=2,
            delay=0.0, interleave=False):
        worker = WorkerConfig(workers=n)
        mesh = make_host_mesh(n)
        sync = SyncConfig(mode, staleness=tau, axis_name=worker.axis,
                          layerwise=layerwise, local_steps=local_steps,
                          collective_delay_ns_per_byte=delay,
                          interleave=interleave)
        opt = make_optimizer(cfg, total_steps=64)
        fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
        state = init_worker_state(cfg, jax.random.key(0), sync, worker, opt)
        losses = []
        for s in range(0, steps, K):
            state, m = fn(state, put_worker_sharded(pipe, s, K, mesh,
                                                    worker))
            losses.extend(np.asarray(m["loss"]).tolist())
        return jax.tree.map(np.asarray, state), losses

    def leaves(t):
        return [np.asarray(l) for l in jax.tree.leaves(t)]
"""


def test_interleave_delay_deterministic_and_allclose_vs_collect():
    """Injected-delay determinism (run-to-run bit-identical) and the
    interleaved tape's agreement with collect-then-walk: losses match to
    float tolerance over 4 steps (the ~1-ulp per-step canonicalisation gap
    compounds through training but stays tiny at this horizon)."""
    out = _run_sub(_SETUP + """
    a, la = run(2, "bsp", layerwise=True, delay=100.0, interleave=True)
    b, lb = run(2, "bsp", layerwise=True, delay=100.0, interleave=True)
    for x, y in zip(leaves(a), leaves(b)):
        np.testing.assert_array_equal(x, y, err_msg="interleave+delay "
                                      "must be deterministic")
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    c, lc = run(2, "bsp", layerwise=True, delay=100.0, interleave=False)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lc),
                               rtol=1e-4, atol=1e-6,
                               err_msg="interleave vs collect losses")
    for x, y in zip(leaves(a["params"]), leaves(c["params"])):
        np.testing.assert_allclose(x, y, rtol=1e-3, atol=1e-5,
                                   err_msg="interleave vs collect params")
    print("OK")
    """)
    assert "OK" in out


def test_localsgd_tau0_is_blocking_boundary_pmean():
    """τ=0 degenerates to the historical blocking boundary average: after
    the K-step boundary every worker holds the pre-boundary worker MEAN
    (computed here from a never-averaging run of the same trajectory)."""
    out = _run_sub(_SETUP + """
    # local_steps=64 -> no boundary inside 2 steps: the pure-local params
    local, _ = run(2, "localsgd", tau=0, steps=2, local_steps=64)
    avg, _ = run(2, "localsgd", tau=0, steps=2, local_steps=2)
    for p_l, p_a in zip(leaves(local["params"]), leaves(avg["params"])):
        np.testing.assert_array_equal(p_a[0], p_a[1],
                                      err_msg="post-boundary params must "
                                      "be worker-identical")
        np.testing.assert_allclose(p_a[0], np.mean(p_l, axis=0),
                                   rtol=0, atol=1e-7,
                                   err_msg="boundary pmean")
    print("OK")
    """)
    assert "OK" in out


def test_localsgd_tau_ring_staleness_shift_and_mean_preservation():
    """τ=1: the first boundary applies the zero-initialised slot (params
    bit-equal the never-averaging run) while writing exactly
    ``pmean(params) - params`` into the ring; the second boundary applies
    that stale correction — params leave the local trajectory but the
    cross-worker mean is preserved (corrections sum to zero)."""
    out = _run_sub(_SETUP + """
    local2, _ = run(2, "localsgd", tau=1, steps=2, local_steps=64)
    ring2, _ = run(2, "localsgd", tau=1, steps=2, local_steps=2)
    mean2 = [np.mean(p, axis=0) for p in leaves(local2["params"])]
    for p_l, p_r, m, h in zip(leaves(local2["params"]),
                              leaves(ring2["params"]), mean2,
                              leaves(ring2["sync"]["lsring"]["h0"])):
        np.testing.assert_array_equal(p_r, p_l,
                                      err_msg="first boundary must be the "
                                      "identity on params (stale slot 0)")
        np.testing.assert_allclose(h, m[None] - p_l, rtol=0, atol=1e-7,
                                   err_msg="ring slot = pmean - params")

    local4, _ = run(2, "localsgd", tau=1, steps=4, local_steps=64)
    ring4, _ = run(2, "localsgd", tau=1, steps=4, local_steps=2)
    diverged = any(not np.array_equal(a, b) for a, b in
                   zip(leaves(local4["params"]), leaves(ring4["params"])))
    assert diverged, "second boundary must apply a nonzero correction"
    for p_l, p_r in zip(leaves(local4["params"]), leaves(ring4["params"])):
        np.testing.assert_allclose(np.mean(p_r, axis=0),
                                   np.mean(p_l, axis=0),
                                   rtol=0, atol=1e-6,
                                   err_msg="corrections must preserve the "
                                   "cross-worker mean")
    print("OK")
    """)
    assert "OK" in out


def test_localsgd_tau_ring_delay_value_neutral():
    """The τ-ring's deadline tokens change timing only: params and losses
    with ``collective_delay_ns_per_byte`` > 0 are bit-identical to the
    delay-off run (the token state itself differs, so compare content)."""
    out = _run_sub(_SETUP + """
    off, l_off = run(2, "localsgd", tau=1, steps=4, local_steps=2)
    on, l_on = run(2, "localsgd", tau=1, steps=4, local_steps=2,
                   delay=200.0)
    np.testing.assert_array_equal(np.asarray(l_off), np.asarray(l_on))
    for k in ("params", "opt"):
        for x, y in zip(leaves(off[k]), leaves(on[k])):
            np.testing.assert_array_equal(x, y, err_msg=k)
    for x, y in zip(leaves(off["sync"]["lsring"]),
                    leaves(on["sync"]["lsring"])):
        np.testing.assert_array_equal(x, y, err_msg="lsring")
    assert "lstok" in on["sync"] and "lstok" not in off["sync"]
    print("OK")
    """)
    assert "OK" in out


def test_layerwise_microbatch_bitexact_vs_batched():
    """The bucket-granular micro-batch accumulator: layerwise bsp+SGD with
    cfg.micro_batches=2 is bit-exact to the batched micro-batch update
    (single path), extending the layerwise bit-exactness pin to n_micro>1.
    """
    import dataclasses

    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.data.mnist import make_dataset
    from repro.data.pipeline import ImagePipeline
    from repro.train.step import (init_train_state, make_optimizer,
                                  make_train_step)

    cfg = dataclasses.replace(C.get("chaos-small"), micro_batches=2)
    imgs, labels = make_dataset(64, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=8, sample_mode="queue")
    states = {}
    for layerwise in (False, True):
        sync = SyncConfig("bsp", layerwise=layerwise)
        opt = make_optimizer(cfg, total_steps=8)
        fn = jax.jit(make_train_step(cfg, sync, opt))
        state = init_train_state(cfg, jax.random.key(0), sync, opt)
        for t in range(2):
            state, metrics = fn(state, pipe.batch_at(t))
        states[layerwise] = (state, float(metrics["loss"]))
    assert np.isfinite(states[True][1])
    assert states[True][1] == states[False][1]
    for a, b in zip(jax.tree.leaves(states[False][0]),
                    jax.tree.leaves(states[True][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg="layerwise micro-batch must "
                                      "be bit-exact vs batched")


def test_roofline_crosscheck_injected_exchange_cost():
    """The injected charge is the roofline collective model made wall-clock
    real: on the blocking schedule, measured exchange cost (delay-on minus
    delay-off us/step) tracks ``parse_collectives(HLO).effective_bytes ×
    delay`` at two delays.  Tolerance is generous — callback dispatch and
    shared-core scheduling ride on top of the charge — but tight enough to
    catch a wrong bytes model (factor-2 errors)."""
    out = _run_sub(_SETUP + """
    import time
    from repro.core.roofline import parse_collectives
    from repro.train.step import make_optimizer as _mk

    def wall(delay):
        worker = WorkerConfig(workers=2)
        mesh = make_host_mesh(2)
        sync = SyncConfig("bsp", layerwise=True, axis_name=worker.axis,
                          collective_delay_ns_per_byte=delay)
        opt = _mk(cfg, total_steps=64)
        fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
        state = init_worker_state(cfg, jax.random.key(0), sync, worker,
                                  opt)
        batches = [put_worker_sharded(pipe, i * 4, 4, mesh, worker)
                   for i in range(3)]
        eff = parse_collectives(
            fn.lower(state, batches[0]).compile().as_text()).effective_bytes
        state, m = fn(state, batches[0])           # compile+warm, untimed
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for b in batches[1:]:
            state, m = fn(state, b)
            jax.block_until_ready(m["loss"])
        return (time.perf_counter() - t0) / 8 * 1e6, eff

    base, eff = wall(0.0)
    assert eff > 0
    for delay in (400.0, 800.0):
        us, _ = wall(delay)
        measured = us - base
        predicted = eff * delay * 1e-3
        ratio = measured / predicted
        assert 0.4 < ratio < 2.5, (delay, measured, predicted, ratio)
        print(f"delay={delay}: ratio={ratio:.2f}")
    print("OK")
    """)
    assert "OK" in out
