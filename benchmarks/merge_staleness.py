"""Merge a partial staleness-τ re-measurement into BENCH_staleness.json.

Workflow (add/refresh one net's column — e.g. the dense-LM cells —
without re-running the whole hours-long CNN convergence grid):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.staleness --nets lm-bench > new.json
    PYTHONPATH=src python -m benchmarks.merge_staleness new.json

Rows whose ``net`` appears in the patch replace the artifact's rows for
that net wholesale; every derived column (``speedup_vs_tau0``,
``speedup_vs_n1``, ``error_delta_vs_tau0``, ``speedup_vs_batched``,
``model_speedup``) and the human-readable ``rows`` entries are recomputed
for the new cells exactly like ``benchmarks/run.py::bench_staleness``
does — baselines come from the patch's own cells, so a partial sweep
missing its τ=0 / N=1 / batched twin yields NaN rather than a stale
cross-measurement ratio.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

DEFAULT_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_staleness.json")


def attach_derived(new_runs: list) -> None:
    """Recompute the derived columns for ``new_runs`` in place, exactly
    like ``bench_staleness`` (baselines resolved within ``new_runs``)."""
    from benchmarks.run import _model_speedup

    lw = lambda r: bool(r.get("layerwise"))
    base = {(r["net"], r["workers"], lw(r)): r for r in new_runs
            if r["tau"] == 0}
    base_n1 = {(r["net"], r["tau"], lw(r)): r for r in new_runs
               if r["workers"] == 1}
    batched = {(r["net"], r["tau"], r["workers"]): r for r in new_runs
               if not lw(r)}
    for r in new_runs:
        b = base.get((r["net"], r["workers"], lw(r)))
        b1 = base_n1.get((r["net"], r["tau"], lw(r)))
        tw = batched.get((r["net"], r["tau"], r["workers"]))
        r["speedup_vs_tau0"] = (r["steps_per_s"] / b["steps_per_s"]
                                if b else float("nan"))
        r["speedup_vs_n1"] = (r["steps_per_s"] / b1["steps_per_s"]
                              if b1 else float("nan"))
        r["error_delta_vs_tau0"] = (r["final_error"] - b["final_error"]
                                    if b else float("nan"))
        r["speedup_vs_batched"] = (r["steps_per_s"] / tw["steps_per_s"]
                                   if lw(r) and tw else float("nan"))
        r["model_speedup"] = _model_speedup(r)


def merge(doc: dict, new_runs: list, note: str | None = None) -> dict:
    nets = {r["net"] for r in new_runs}
    runs = [r for r in doc["runs"] if r["net"] not in nets]
    attach_derived(new_runs)
    runs.extend(new_runs)
    lw = lambda r: bool(r.get("layerwise"))
    runs.sort(key=lambda r: (r["net"], r["workers"], r["tau"], lw(r)))
    doc["runs"] = runs
    doc["timestamp"] = time.time()
    if note:
        doc["note"] = doc.get("note", "") + "; " + note

    rows = [row for row in doc.get("rows", [])
            if not any(f"staleness/{n}/" in row["name"] for n in nets)]
    for r in new_runs:
        kind = "layerwise" if lw(r) else "batched"
        rows.append({
            "name": f"staleness/{r['net']}/tau{r['tau']}/N{r['workers']}"
                    f"/{kind}",
            "us_per_call": r["us_per_step"],
            "derived": f"{r['steps_per_s']:.1f}steps_per_s"
                       f"_err={r['final_error']:.4f}"
                       f"_derr={r['error_delta_vs_tau0']:+.4f}"
                       f"_speedup_tau0={r['speedup_vs_tau0']:.2f}x"})
    doc["rows"] = rows
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("patch", help="JSON from benchmarks.staleness "
                                  "--nets ... ('-' reads stdin)")
    ap.add_argument("--artifact", default=os.path.normpath(DEFAULT_ARTIFACT))
    ap.add_argument("--note", default=None,
                    help="appended to the artifact's note field")
    args = ap.parse_args()

    with open(args.artifact) as f:
        doc = json.load(f)
    if args.patch == "-":
        new_runs = json.load(sys.stdin)["runs"]
    else:
        with open(args.patch) as f:
            new_runs = json.load(f)["runs"]
    if not new_runs:
        sys.exit("patch contains no runs")
    doc = merge(doc, new_runs, args.note)
    with open(args.artifact, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"merged {len(new_runs)} rows "
          f"(nets: {sorted({r['net'] for r in new_runs})}) "
          f"into {args.artifact}; total {len(doc['runs'])}")


if __name__ == "__main__":
    main()
