"""Continuous-batching serving benchmark (DESIGN.md §9).

For each served family (dense GQA, MLA, state) this module replays the
same seeded Poisson request trace through the ServeEngine twice —

  * ``batched`` prefill: whole right-padded prompts in ONE dispatch
    through the q_offset-aware flash attention;
  * ``loop`` prefill: the pre-§9 token-at-a-time reference loop —

and reports tokens/sec, p50/p99 per-token latency (pure-decode step wall
time: every active request receives exactly one token per step), and the
batched-over-loop prefill speedup.  A roofline sanity row cross-checks the
measured decode step against the compiled dispatch's analytic bound
(core/roofline.py): on any backend measured >= bound must hold — the bound
uses TPU v5e roofs, so the CPU ratio is large but the direction is pinned.

Each engine is warmed by replaying the trace once untimed, so every
(A, T) prefill bucket and the decode program are compiled before timing.

Prints one JSON document {"runs": [...], "roofline": {...}} to stdout;
progress lines go to stderr.  Spawned by ``benchmarks/run.py --only
serve``.

    PYTHONPATH=src python -m benchmarks.serve [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCHS = ("qwen3-14b", "minicpm3-4b", "rwkv6-1.6b")


def _log(msg):
    print(msg, file=sys.stderr, flush=True)


def _replay(eng, trace):
    """Run a trace to completion; returns (finished, step_stats) where each
    step stat is (wall_s, admitted_this_step, tokens_this_step)."""
    for r in trace:
        eng.submit(r)
    finished, stats = [], []
    while eng.pending or eng.active:
        pre0 = eng.counters["prefill_dispatch"]
        tok0 = (eng.counters["prefill_tokens"]
                + eng.counters["decode_tokens"])
        t0 = time.perf_counter()
        finished.extend(eng.step())
        dt = time.perf_counter() - t0
        stats.append((dt, eng.counters["prefill_dispatch"] - pre0,
                      eng.counters["prefill_tokens"]
                      + eng.counters["decode_tokens"] - tok0))
    return finished, stats


def _bench_mode(arch, mode, trace, slots, max_seq, seed,
                temperature=0.0, top_p=1.0):
    from repro.serve.engine import ServeEngine
    eng = ServeEngine(arch, slots=slots, max_seq=max_seq, seed=seed,
                      prefill_mode=mode, temperature=temperature,
                      top_p=top_p)
    sampling = ("greedy" if temperature <= 0
                else f"t{temperature:g}_p{top_p:g}")
    _log(f"[serve-bench] {arch}/{mode}/{sampling}: warmup replay")
    _replay(eng, [r.__class__(**vars(r)) for r in trace])
    eng.clock, eng.step_idx = 0.0, 0
    _log(f"[serve-bench] {arch}/{mode}: measured replay")
    t0 = time.perf_counter()
    finished, stats = _replay(eng, [r.__class__(**vars(r)) for r in trace])
    wall = time.perf_counter() - t0
    toks = sum(s[2] for s in stats)
    decode_steps = [s[0] for s in stats if s[1] == 0 and s[2] > 0]
    lat = (np.percentile(decode_steps, [50, 99]) if decode_steps
           else np.array([float("nan")] * 2))
    return {
        "arch": arch, "mode": mode, "sampling": sampling, "slots": slots,
        "requests": len(trace), "tokens": int(toks), "wall_s": wall,
        "tokens_per_s": toks / wall,
        "p50_token_latency_s": float(lat[0]),
        "p99_token_latency_s": float(lat[1]),
        "gen_checksum": int(sum(int(f.tokens.sum()) for f in finished)
                            % (1 << 31)),
    }, eng


def _roofline_row(eng, arch):
    """Analytic bound for ONE decode dispatch of the warmed engine."""
    import jax.numpy as jnp
    from repro.core import roofline

    toks = jnp.asarray(eng.last_tok)
    cur = jnp.asarray(eng.kv.cursors)
    rids = jnp.asarray(eng.slot_rid)
    poss = jnp.zeros_like(rids)
    compiled = eng._decode.lower(eng.params, eng.kv.tree, toks, cur,
                                 rids, poss).compile()
    n_active_params = eng.cfg.active_param_count()
    rl = roofline.analyze(compiled, n_devices=1,
                          model_flops_total=2.0 * n_active_params
                          * eng.kv.slots)
    return {"arch": arch, "decode_bound_s": rl.bound_s,
            "dominant": rl.dominant,
            "flops_per_dispatch": rl.flops,
            "bytes_per_dispatch": rl.bytes_accessed,
            "note": "bound uses TPU v5e roofs; sanity contract is "
                    "measured_p50 >= bound on every backend"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.serve.engine import poisson_trace
    import repro.configs as C

    slots, requests, gen = (2, 4, 4) if args.quick else (4, 10, 8)
    prompt_lens = (4, 12) if args.quick else (6, 24)
    max_seq = 64
    runs, roofline_info = [], {}
    for arch in ARCHS:
        cfg = C.smoke(arch)
        trace = poisson_trace(args.seed, requests, rate=1.0,
                              vocab=cfg.vocab_size,
                              prompt_lens=prompt_lens, max_new=gen)
        per_mode = {}
        for mode in ("batched", "loop"):
            r, eng = _bench_mode(arch, mode, trace, slots, max_seq,
                                 args.seed)
            per_mode[mode] = r
            runs.append(r)
            _log(f"[serve-bench] {arch}/{mode}: "
                 f"{r['tokens_per_s']:.1f} tok/s "
                 f"p50={r['p50_token_latency_s'] * 1e3:.1f}ms "
                 f"p99={r['p99_token_latency_s'] * 1e3:.1f}ms")
            if mode == "batched":
                rl = _roofline_row(eng, arch)
                rl["measured_p50_s"] = r["p50_token_latency_s"]
                rl["measured_over_bound"] = (
                    r["p50_token_latency_s"] / rl["decode_bound_s"]
                    if rl["decode_bound_s"] else float("nan"))
                roofline_info[arch] = rl
        b, l = per_mode["batched"], per_mode["loop"]
        if b["gen_checksum"] != l["gen_checksum"]:
            _log(f"[serve-bench] WARNING {arch}: batched/loop token "
                 f"checksums differ ({b['gen_checksum']} vs "
                 f"{l['gen_checksum']})")
        speedup = b["tokens_per_s"] / l["tokens_per_s"]
        b["prefill_speedup_vs_loop"] = speedup
        _log(f"[serve-bench] {arch}: batched prefill speedup x{speedup:.2f}")
        # sampling-mode column: the same trace through seeded top-p
        # sampling fused into the decode dispatch (cost of sampling =
        # this row vs the greedy batched row)
        rs, _ = _bench_mode(arch, "batched", trace, slots, max_seq,
                            args.seed, temperature=0.8, top_p=0.9)
        rs["sampling_overhead_vs_greedy"] = (
            b["tokens_per_s"] / rs["tokens_per_s"])
        runs.append(rs)
        _log(f"[serve-bench] {arch}: sampled decode "
             f"{rs['tokens_per_s']:.1f} tok/s "
             f"(x{rs['sampling_overhead_vs_greedy']:.2f} vs greedy)")
    print(json.dumps({"runs": runs, "roofline": roofline_info}, indent=1))


if __name__ == "__main__":
    main()
