"""Merge a partial worker-scaling re-measurement into BENCH_scaling.json.

Workflow (refresh only some modes' rows after a sync-engine change,
instead of re-running the whole hours-long grid):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.scaling --modes chaos > new.json
    PYTHONPATH=src python -m benchmarks.merge_scaling new.json

Rows whose ``(net, mode)`` pair appears in the patch replace the
artifact's rows for that pair wholesale (so an ``--nets lm-bench`` patch
adds/refreshes only the dense-LM column and leaves the CNN grid alone);
``speedup_vs_1`` / ``model_speedup`` and the human-readable ``rows``
entries are recomputed for the new cells exactly like
``benchmarks/run.py::bench_scaling`` does (speedup baselines come from
the patch's own N=1 cells, so a partial sweep without N=1 yields NaN
rather than a stale cross-engine ratio).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

DEFAULT_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_scaling.json")


def merge(doc: dict, new_runs: list, note: str | None = None) -> dict:
    from benchmarks.run import _model_speedup

    pairs = {(r["net"], r["mode"]) for r in new_runs}
    runs = [r for r in doc["runs"]
            if (r["net"], r["mode"]) not in pairs]
    base = {(r["net"], r["mode"], r["use_kernel"]): r["steps_per_s"]
            for r in new_runs if r["workers"] == 1}
    for r in new_runs:
        b = base.get((r["net"], r["mode"], r["use_kernel"]))
        r["speedup_vs_1"] = r["steps_per_s"] / b if b else float("nan")
        r["model_speedup"] = _model_speedup(r)
    runs.extend(new_runs)
    runs.sort(key=lambda r: (r["net"], r["use_kernel"], r["mode"],
                             r["workers"]))
    doc["runs"] = runs
    doc["timestamp"] = time.time()
    if note:
        doc["note"] = doc.get("note", "") + "; " + note

    rows = [row for row in doc.get("rows", [])
            if not any(f"scaling/{n}/{m}/" in row["name"]
                       for n, m in pairs)]
    for r in new_runs:
        kind = "kernel" if r["use_kernel"] else "xla"
        rows.append({
            "name": f"scaling/{r['net']}/{r['mode']}/{kind}/N{r['workers']}",
            "us_per_call": r["us_per_step"],
            "derived": f"{r['steps_per_s']:.1f}steps_per_s_speedup="
                       f"{r['speedup_vs_1']:.2f}x_model="
                       f"{r['model_speedup']:.2f}x"})
    doc["rows"] = rows
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("patch", help="JSON from benchmarks.scaling --modes ... "
                                  "('-' reads stdin)")
    ap.add_argument("--artifact", default=os.path.normpath(DEFAULT_ARTIFACT))
    ap.add_argument("--note", default=None,
                    help="appended to the artifact's note field")
    args = ap.parse_args()

    with open(args.artifact) as f:
        doc = json.load(f)
    if args.patch == "-":
        new_runs = json.load(sys.stdin)["runs"]
    else:
        with open(args.patch) as f:
            new_runs = json.load(f)["runs"]
    if not new_runs:
        sys.exit("patch contains no runs")
    doc = merge(doc, new_runs, args.note)
    with open(args.artifact, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"merged {len(new_runs)} rows "
          f"(cells: {sorted({(r['net'], r['mode']) for r in new_runs})}) "
          f"into {args.artifact}; total {len(doc['runs'])}")


if __name__ == "__main__":
    main()
