"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper's table reports: % time, minutes, speedup, GFLOP/s, ...) and
persists each section's rows to ``BENCH_<section>.json`` (see ``--out``)
so the perf trajectory accumulates across PRs.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
                                            [--out DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROWS = []


def row(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, *args, n=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


_KERNEL_OK = None


def _kernel_path_available():
    """Probe the Pallas interpret path once (tiny conv launch): on hosts
    where ``jax.experimental.pallas`` is missing or broken, the kernel
    sections/cells skip with an actionable row instead of erroring the
    harness — the XLA rows still measure."""
    global _KERNEL_OK
    if _KERNEL_OK is None:
        try:
            from repro.kernels import ops as kops
            jax.block_until_ready(kops.conv2d_valid(
                jnp.zeros((1, 6, 6, 1), jnp.float32),
                jnp.zeros((3, 3, 1, 2), jnp.float32)))
            _KERNEL_OK = (True, "")
        except Exception as e:  # noqa: BLE001 — any failure means "skip"
            _KERNEL_OK = (False, repr(e)[:200])
    return _KERNEL_OK


# ---------------------------------------------------------------------------
# Table 1 / Table 5 analogue: per-layer time split of the CNN training step
# ---------------------------------------------------------------------------
def bench_layer_times(quick=False):
    import repro.configs as C
    from repro.models import cnn, layers as L

    for arch in (["chaos-small"] if quick else
                 ["chaos-small", "chaos-medium", "chaos-large"]):
        cfg = C.get(arch)
        params = cnn.build_params(cfg, L.InitFactory(jax.random.key(0),
                                                     jnp.float32))
        B = 8
        x = jax.random.uniform(jax.random.key(1), (B, 29, 29, 1))
        y = jax.random.randint(jax.random.key(2), (B,), 0, 10)
        batch = {"images": x, "labels": y}

        full = jax.jit(jax.grad(lambda p: cnn.loss_fn(p, batch, cfg)[0]))
        us_full = _timeit(full, params, n=5)

        # time conv fwd+bwd by differentiating w.r.t. conv params only
        conv_keys = [k for k in params if k.startswith("conv")]
        conv_p = {k: params[k] for k in conv_keys}
        rest = {k: v for k, v in params.items() if k not in conv_keys}
        conv_only = jax.jit(jax.grad(
            lambda cp: cnn.loss_fn({**rest, **cp}, batch, cfg)[0]))
        us_conv = _timeit(conv_only, conv_p, n=5)
        frac = us_conv / us_full * 100
        row(f"layer_times/{arch}/full_step", us_full,
            f"conv_share~{frac:.0f}%_paper_93.7%")


# ---------------------------------------------------------------------------
# Table 8 + Table 9 + Result 3: the paper's performance model
# ---------------------------------------------------------------------------
def bench_perf_model(quick=False):
    from repro.core import perf_model as pm
    t8 = pm.table8()
    for arch in ("small", "medium", "large"):
        for p in (480, 960, 1920, 3840):
            row(f"table8/{arch}/{p}T", 0.0,
                f"pred={t8[arch][p]:.1f}min_paper={pm.PAPER_TABLE8[arch][p]}min")
    for arch in ("small", "medium", "large"):
        row(f"result3/speedup_vs_phi1T/{arch}", 0.0,
            f"{pm.predict_speedup(arch, 244):.1f}x_paper_up_to_103x")
    row("table9/small/240T/70ep", 0.0,
        f"pred={pm.predict_time('small', 240) / 60:.1f}min_paper=8.9min")
    row("table9/small/240T/140ep", 0.0,
        f"pred={pm.predict_time('small', 240, ep=140) / 60:.1f}min_paper=17.6min")


# ---------------------------------------------------------------------------
# Fig 5-9 measured analogue: CHAOS sync-mode step times (single host device;
# the cross-replica benefit is quantified by the roofline collective term)
# ---------------------------------------------------------------------------
def bench_sync_modes(quick=False):
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.train.step import (init_train_state, make_optimizer,
                                  make_train_step)
    from repro.data.pipeline import ImagePipeline
    from repro.data.mnist import make_dataset

    cfg = C.get("chaos-small")
    imgs, labels = make_dataset(256, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=32)
    batch = pipe.batch_at(0)
    for mode in ("bsp", "chaos", "localsgd"):
        sync = SyncConfig(mode=mode)
        opt = make_optimizer(cfg, total_steps=100)
        step = jax.jit(make_train_step(cfg, sync, opt))
        state = init_train_state(cfg, jax.random.key(0), sync, opt)
        us = _timeit(lambda s, b: step(s, b)[0], state, batch, n=5)
        row(f"sync_step/chaos-small/{mode}", us,
            f"{32 / (us / 1e6):.0f}img_per_s")


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (paper Listing 1: vectorised conv loops) — tuned
# vs the hard-coded batch_block=8 whole-map baseline, per Table-2 net
# ---------------------------------------------------------------------------
# (B, H, W, Cin, K, Cout) conv shapes of the paper's three Table-2 nets
NET_CONV_SHAPES = {
    "small": [(8, 29, 29, 1, 4, 5), (8, 13, 13, 5, 5, 10)],
    "medium": [(8, 29, 29, 1, 4, 20), (8, 13, 13, 20, 5, 40)],
    "large": [(8, 26, 26, 20, 5, 60), (8, 11, 11, 60, 6, 100)],
}


def bench_kernels(quick=False):
    ok, why = _kernel_path_available()
    if not ok:
        row("kernel/SKIPPED", 0.0,
            f"pallas_unavailable_{why[:80]}_install_jax_with_pallas_or_"
            f"set_REPRO_PALLAS_INTERPRET=1")
        return {"skipped": True, "reason": why}

    from repro.kernels import autotune as AT
    from repro.kernels import conv2d as CK
    from repro.kernels import ops as kops
    from repro.kernels import ref

    detail = []
    nets = ["small"] if quick else ["small", "medium", "large"]
    iters = 1 if quick else 2
    # match the training path's interpret mode so tuned configs land under
    # the cache key that ops._fwd_cfg/_bwd_cfg actually look up on this host
    interp = kops._interpret()
    for net in nets:
        for (B, H, W, Cin, K, Cout) in NET_CONV_SHAPES[net]:
            x = jax.random.normal(jax.random.key(0), (B, H, W, Cin),
                                  jnp.float32)
            w = jax.random.normal(jax.random.key(1), (K, K, Cin, Cout),
                                  jnp.float32) * 0.1
            dy = jax.random.normal(jax.random.key(2),
                                   (B, H - K + 1, W - K + 1, Cout),
                                   jnp.float32)
            b = jax.random.normal(jax.random.key(3), (Cout,),
                                  jnp.float32) * 0.1
            # tune the fused variants models/cnn.py actually executes
            y = jnp.tanh(ref.conv2d_valid_ref(x, w) + b)
            flops = 2 * B * (H - K + 1) * (W - K + 1) * K * K * Cin * Cout
            cfg, rep = AT.tune_conv_fwd(x, w, b, activation="tanh",
                                        iters=iters, interpret=interp)
            bcfg, brep = AT.tune_conv_bwd(x, dy, w, y, iters=iters,
                                          interpret=interp)
            shp = f"{net}/conv_{H}x{W}x{Cin}_k{K}_{Cout}"
            row(f"kernel/fwd/{shp}/default", rep["baseline_us"],
                f"{flops / rep['baseline_us'] / 1e3:.2f}GFLOPs")
            row(f"kernel/fwd/{shp}/tuned", rep["best_us"],
                f"{rep['baseline_us'] / rep['best_us']:.2f}x_cfg={cfg}")
            row(f"kernel/bwd_fused/{shp}/default", brep["baseline_us"],
                f"vs_tuned_{brep['baseline_us'] / brep['best_us']:.2f}x")
            # best_us <= baseline_us by construction: the batch_block=8
            # baseline is always in the measured candidate set
            detail.append({
                "net": net,
                "shape": [B, H, W, Cin, K, Cout],
                "fwd": {"variant": "bias_tanh",
                        "default_us": rep["baseline_us"],
                        "tuned_us": rep["best_us"], "tuned_config": cfg,
                        "candidates": rep["candidates"]},
                "bwd_fused": {"variant": "dtanh",
                              "default_us": brep["baseline_us"],
                              "tuned_us": brep["best_us"],
                              "tuned_config": bcfg},
            })

    # fused vs split backward + Pallas-vs-XLA reference points (large conv2)
    B, H, W, Cin, K, Cout = 8, 26, 26, 20, 5, 60
    x = jax.random.normal(jax.random.key(0), (B, H, W, Cin), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (K, K, Cin, Cout),
                          jnp.float32) * 0.1
    dy = jax.random.normal(jax.random.key(2), (B, 22, 22, Cout), jnp.float32)
    flops = 2 * B * 22 * 22 * K * K * Cin * Cout
    us_p = _timeit(jax.jit(kops.conv2d_valid), x, w, n=3)
    us_x = _timeit(jax.jit(ref.conv2d_valid_ref), x, w, n=3)
    mode = "interp" if interp else "compiled"
    row(f"kernel/conv2d_pallas_{mode}", us_p,
        f"{flops / us_p / 1e3:.2f}GFLOPs")
    row("kernel/conv2d_xla", us_x, f"{flops / us_x / 1e3:.2f}GFLOPs")
    us_fused = _timeit(jax.jit(lambda x, dy, w: CK.conv2d_bwd_fused(
        x, dy, w, interpret=interp)), x, dy, w, n=3)
    us_split = _timeit(jax.jit(lambda x, dy, w: (
        CK.conv2d_dx(dy, w, x.shape, interpret=interp),
        CK.conv2d_dw(x, dy, w.shape, interpret=interp))),
        x, dy, w, n=3)
    row("kernel/conv_bwd_fused_1launch", us_fused,
        f"vs_split_{us_split / us_fused:.2f}x")
    row("kernel/conv_bwd_split_2launch", us_split, "baseline")

    from repro.models import layers as L
    B, T, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(jax.random.key(2), (B, T, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.key(3), (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(jax.random.key(4), (B, T, Hkv, D), jnp.float32)
    fl = jax.jit(lambda q, k, v: L.flash_attention(q, k, v, causal=True))
    us_f = _timeit(fl, q, k, v, n=3)
    aflops = 4 * B * Hq * T * T * D / 2
    row("kernel/flash_attention_1k", us_f, f"{aflops / us_f / 1e3:.2f}GFLOPs")

    # training-grade flash attention (DESIGN.md §10): tuned Pallas forward
    # + LSE-saving blockwise backward vs the pure-jnp flash path, at the
    # dense-LM bench net's per-shard training shape — the same cache key
    # ``flash_attention_train`` resolves inside the worker-mesh cells
    flash_detail = None
    if not quick:
        from repro.kernels import flash_attention as FA
        B, T, Hq, Hkv, D = 1, 512, 4, 2, 16  # lm-bench per-shard GQA shape
        qt = jax.random.normal(jax.random.key(5), (B, T, Hq, D), jnp.float32)
        kt = jax.random.normal(jax.random.key(6), (B, T, Hkv, D), jnp.float32)
        vt = jax.random.normal(jax.random.key(7), (B, T, Hkv, D), jnp.float32)
        to_kern = lambda x: x.transpose(0, 2, 1, 3)
        fcfg, frep = AT.tune_flash_attention(
            to_kern(qt), to_kern(kt), to_kern(vt), iters=2,
            interpret=interp)
        row("kernel/flash_fwd_T512/default", frep["baseline_us"],
            "512x512_baseline")
        row("kernel/flash_fwd_T512/tuned", frep["best_us"],
            f"{frep['baseline_us'] / frep['best_us']:.2f}x_cfg={fcfg}")
        grad_j = jax.jit(jax.grad(
            lambda q, k, v: (L.flash_attention(q, k, v,
                                               causal=True) ** 2).mean(),
            argnums=(0, 1, 2)))
        grad_p = jax.jit(jax.grad(
            lambda q, k, v: (FA.flash_attention_train(
                q, k, v, causal=True) ** 2).mean(), argnums=(0, 1, 2)))
        us_j = _timeit(grad_j, qt, kt, vt, n=3, warmup=1)
        us_p = _timeit(grad_p, qt, kt, vt, n=3, warmup=1)
        row("kernel/flash_train_T512/jnp", us_j, "blockwise_jnp_fwd+bwd")
        row("kernel/flash_train_T512/pallas_tuned", us_p,
            f"vs_jnp_{us_j / us_p:.2f}x_lse_saving_bwd")
        flash_detail = {
            "shape_bthd": [B, T, Hq, D], "kv_heads": Hkv,
            "fwd": {"default_us": frep["baseline_us"],
                    "tuned_us": frep["best_us"], "tuned_config": fcfg,
                    "candidates": frep["candidates"]},
            "train_grad": {"jnp_us": us_j, "pallas_tuned_us": us_p,
                           "speedup": us_j / us_p},
        }
    return {"conv_shapes": detail, "flash": flash_detail,
            "autotune_cache": AT.cache_path()}


# ---------------------------------------------------------------------------
# End-to-end training throughput: steps/sec + time-per-epoch (the paper's
# actual deliverable — its speedup curves are epoch times, Tables 8/9) for
# the three Table-2 nets, at superstep K in {1, 8, 32}, Pallas kernels
# on/off.  First end-to-end point on the perf trajectory (BENCH_train.json).
# ---------------------------------------------------------------------------
EPOCH_IMAGES = 60_000  # paper's MNIST train-set size
TRAIN_BATCH = 8


def bench_train(quick=False):
    import dataclasses as DC

    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.data.mnist import make_dataset
    from repro.data.pipeline import ImagePipeline
    from repro.train.step import (init_train_state, make_optimizer,
                                  make_superstep)

    nets = ["chaos-small"] if quick else ["chaos-small", "chaos-medium",
                                          "chaos-large"]
    supersteps = [1, 8, 32]
    imgs, labels = make_dataset(512, seed=0)
    detail = []
    kernel_modes = (False, True)
    ok, why = _kernel_path_available()
    if not ok:
        row("train/kernel_SKIPPED", 0.0, f"pallas_unavailable_{why[:80]}")
        kernel_modes = (False,)
    for net in nets:
        base_cfg = C.get(net)
        for use_kernel in kernel_modes:
            cfg = DC.replace(base_cfg, use_kernel=use_kernel)
            sync = SyncConfig("bsp")
            opt = make_optimizer(cfg, total_steps=4096)
            super_fn = jax.jit(make_superstep(cfg, sync, opt),
                               donate_argnums=(0,))
            pipe = ImagePipeline(imgs, labels, batch=TRAIN_BATCH,
                                 sample_mode="queue")
            # interpret-mode Pallas is orders slower on CPU: measure fewer
            # steps there (the K-scaling ratio is what matters, not the
            # absolute interpreter floor)
            target = (8 if quick else 16) if use_kernel else 64
            by_k = {}
            for K in supersteps:
                state = init_train_state(cfg, jax.random.key(0), sync, opt)
                step = 0
                measured_steps = 0
                elapsed = 0.0
                while measured_steps < target:
                    # mirror the driver: host batch build + device transfer
                    # + one dispatch + ONE host sync on the (K,) loss vector
                    t0 = time.perf_counter()
                    batch = jax.device_put(pipe.superstep_at(step, K))
                    state, metrics = super_fn(state, batch)
                    np.asarray(metrics["loss"])
                    dt = time.perf_counter() - t0
                    if step > 0:  # first dispatch = compile, not timed
                        elapsed += dt
                        measured_steps += K
                    step += K
                us_per_step = elapsed / measured_steps * 1e6
                sps = 1e6 / us_per_step
                epoch_min = (EPOCH_IMAGES / TRAIN_BATCH) * (us_per_step
                                                            / 1e6) / 60
                by_k[K] = us_per_step
                kind = "kernel" if use_kernel else "xla"
                row(f"train/{net}/{kind}/K{K}", us_per_step,
                    f"{sps:.1f}steps_per_s_epoch~{epoch_min:.2f}min")
                detail.append({
                    "net": net, "use_kernel": use_kernel, "superstep": K,
                    "us_per_step": us_per_step, "steps_per_s": sps,
                    "epoch_min": epoch_min,
                    "batch": TRAIN_BATCH, "measured_steps": measured_steps,
                })
            kind = "kernel" if use_kernel else "xla"
            row(f"train/{net}/{kind}/superstep_speedup", by_k[1],
                f"K32_vs_K1_{by_k[1] / by_k[32]:.2f}x")
    return {"runs": detail, "epoch_images": EPOCH_IMAGES}


# ---------------------------------------------------------------------------
# Result 3 / Tables 7-9 measured analogue: CHAOS worker scaling.  Runs the
# worker-mesh superstep path (shard_map over forced host devices) for the
# three Table-2 nets x 3 sync modes x workers {1,2,4,8} x kernels on/off in
# ONE subprocess (XLA_FLAGS must be set before jax initialises), then puts
# measured speedup next to the paper's performance-model prediction.
# ---------------------------------------------------------------------------
SCALING_DEVICES = 8
PAPER_ARCH = {"chaos-small": "small", "chaos-medium": "medium",
              "chaos-large": "large"}


def _model_speedup(r: dict) -> float:
    """Listing-2 predicted speedup for a worker-mesh run row.  Table-2 CNN
    nets map straight onto the paper's op-count tables; other nets (the
    dense-LM column) must carry their own per-sample op counts in the run
    dict (``lm_fprop``/``lm_bprop``, emitted by benchmarks/scaling.py), and
    are registered with the perf model on the fly — rows with neither get
    NaN instead of a KeyError that would void the whole artifact."""
    from repro.core import perf_model as pm

    key = PAPER_ARCH.get(r["net"])
    if key is None:
        if "lm_fprop" not in r:
            return float("nan")
        key = r["net"]
        pm.register_arch(key, fprop=r["lm_fprop"], bprop=r["lm_bprop"])
    return pm.predict_speedup(key, r["workers"])


def _run_grid_subprocess(module: str, quick: bool) -> list:
    """Run a worker-mesh benchmark module in its own process with
    ``SCALING_DEVICES`` forced host devices (XLA_FLAGS must be set before
    jax initialises) and return its ``runs`` list.  stdout (the JSON
    document) is captured; stderr is inherited so per-cell progress lines
    stream live — a full grid runs for a long time and silent buffering
    would hide all progress."""
    import re
    import subprocess

    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", "")).strip()
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{SCALING_DEVICES}").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", module]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, stdout=subprocess.PIPE, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=14000)
    if out.returncode != 0:
        raise RuntimeError(
            f"{module} subprocess failed with rc={out.returncode} "
            f"(its stderr streamed above)")
    return json.loads(out.stdout)["runs"]


def bench_scaling(quick=False):
    runs = _run_grid_subprocess("benchmarks.scaling", quick)
    base = {(r["net"], r["mode"], r["use_kernel"]): r["steps_per_s"]
            for r in runs if r["workers"] == 1}
    for r in runs:
        b = base.get((r["net"], r["mode"], r["use_kernel"]))
        # nan, not None: a missing N=1 baseline (edited worker sweep,
        # partial run) must not crash the row formatting below and throw
        # away an hours-long measurement
        r["speedup_vs_1"] = r["steps_per_s"] / b if b else float("nan")
        # paper performance-model cross-check: N workers ~ N Phi threads
        r["model_speedup"] = _model_speedup(r)
        kind = "kernel" if r["use_kernel"] else "xla"
        row(f"scaling/{r['net']}/{r['mode']}/{kind}/N{r['workers']}",
            r["us_per_step"],
            f"{r['steps_per_s']:.1f}steps_per_s_speedup="
            f"{r['speedup_vs_1']:.2f}x_model={r['model_speedup']:.2f}x")
    return {"runs": runs, "batch": runs[0]["batch"] if runs else None,
            "superstep": runs[0]["superstep"] if runs else None,
            "forced_devices": SCALING_DEVICES,
            "note": "forced host devices share one CPU; speedup_vs_1 "
                    "validates the worker path + overhead trend, "
                    "model_speedup is the paper's Listing-2 prediction "
                    "for the same worker count"}


# ---------------------------------------------------------------------------
# Result 1-2 / Tables 4-6 analogue: staleness-τ CHAOS convergence study.
# Runs the worker-mesh chaos(τ) path (τ=0 ≡ bsp by construction) for the
# Table-2 nets × τ × worker counts, recording steps/sec AND final error so
# the paper's "asynchrony does not significantly degrade accuracy" claim is
# measured, with the τ=0 cell as the synchronous baseline and the Listing-2
# model prediction per worker count.
# ---------------------------------------------------------------------------
def bench_staleness(quick=False):
    runs = _run_grid_subprocess("benchmarks.staleness", quick)
    # baselines are keyed WITHIN a layerwise flavour (τ=0 layerwise bsp is
    # the layerwise rows' synchronous baseline); speedup_vs_batched then
    # compares each layerwise row against its batched twin — the
    # per-layer-exchange overlap column
    lw = lambda r: bool(r.get("layerwise"))
    base = {(r["net"], r["workers"], lw(r)): r for r in runs
            if r["tau"] == 0}
    base_n1 = {(r["net"], r["tau"], lw(r)): r for r in runs
               if r["workers"] == 1}
    batched = {(r["net"], r["tau"], r["workers"]): r for r in runs
               if not lw(r)}
    for r in runs:
        b = base.get((r["net"], r["workers"], lw(r)))
        b1 = base_n1.get((r["net"], r["tau"], lw(r)))
        tw = batched.get((r["net"], r["tau"], r["workers"]))
        r["speedup_vs_tau0"] = (r["steps_per_s"] / b["steps_per_s"]
                                if b else float("nan"))
        r["speedup_vs_n1"] = (r["steps_per_s"] / b1["steps_per_s"]
                              if b1 else float("nan"))
        r["error_delta_vs_tau0"] = (r["final_error"] - b["final_error"]
                                    if b else float("nan"))
        r["speedup_vs_batched"] = (r["steps_per_s"] / tw["steps_per_s"]
                                   if lw(r) and tw else float("nan"))
        r["model_speedup"] = _model_speedup(r)
        kind = "layerwise" if lw(r) else "batched"
        row(f"staleness/{r['net']}/tau{r['tau']}/N{r['workers']}/{kind}",
            r["us_per_step"],
            f"{r['steps_per_s']:.1f}steps_per_s_err={r['final_error']:.4f}"
            f"_derr={r['error_delta_vs_tau0']:+.4f}"
            f"_speedup_tau0={r['speedup_vs_tau0']:.2f}x")
    return {"runs": runs, "forced_devices": SCALING_DEVICES,
            "note": "tau=0 IS bsp (the chaos strategy resolves to the bsp "
                    "object at staleness 0); error columns are hardware-"
                    "independent; forced host devices share one CPU, so "
                    "steps_per_s validates the harness + overhead trend "
                    "and model_speedup is the paper's Listing-2 prediction "
                    "for the same worker count"}


# ---------------------------------------------------------------------------
# Elastic resize: membership-change latency + throughput recovery
# (DESIGN.md §7; CI's preemption-injection smoke uploads this section)
# ---------------------------------------------------------------------------
def bench_elastic(quick=False):
    runs = _run_grid_subprocess("benchmarks.elastic", quick)
    for r in runs:
        row(f"elastic/{r['label']}/{r['from']}to{r['to']}",
            r["latency_s"] * 1e6,
            f"path={r['path']}_first_superstep="
            f"{r['first_superstep_s'] * 1e3:.0f}ms_steps_per_s="
            f"{r['steps_per_s_before']:.1f}->{r['steps_per_s_after']:.1f}")
    return {"runs": runs, "forced_devices": SCALING_DEVICES,
            "note": "latency_s is the re-slot + rebuild cost from "
                    "ResizeOutcome; the first post-resize superstep "
                    "carries the recompile and is reported separately; "
                    "forced host devices share one CPU, so steps_per_s "
                    "validates recovery, not hardware scaling"}


# ---------------------------------------------------------------------------
# Overlap study (DESIGN.md §8): is the layerwise per-bucket exchange hidden
# behind backward compute?  Interleaved (backprop-time bucket collectives)
# vs collect-then-walk under injected per-byte collective latency, with the
# roofline collective-bytes model as the predicted blocking cost.
# ---------------------------------------------------------------------------
def bench_overlap(quick=False):
    runs = _run_grid_subprocess("benchmarks.overlap", quick)
    base = {(r["net"], r["workers"], r["schedule"]): r["us_per_step"]
            for r in runs if r["delay_ns_per_byte"] == 0}
    collect = {(r["net"], r["workers"], r["delay_ns_per_byte"]): r
               for r in runs if r["schedule"] == "collect"}
    for r in runs:
        d = r["delay_ns_per_byte"]
        tw = collect.get((r["net"], r["workers"], d))
        # interleaved speedup vs the blocking schedule at the same delay —
        # the overlap win (nan on the collect rows' own delay-0 baselines)
        r["speedup_vs_collect"] = (tw["us_per_step"] / r["us_per_step"]
                                   if tw and r["schedule"] == "interleave"
                                   else float("nan"))
        pred = r.get("predicted_exchange_us")
        # roofline cross-check: measured blocking exchange / predicted
        # bytes-x-delay (meaningful on the collect rows, where the whole
        # charge is synchronous; interleaved rows land BELOW 1 by design)
        r["exchange_vs_roofline"] = (r["exchange_us"] / pred
                                     if pred else float("nan"))
        if r["schedule"] == "interleave" and tw and pred:
            r["hidden_us"] = tw["exchange_us"] - r["exchange_us"]
            r["hidden_frac_of_predicted"] = r["hidden_us"] / pred
        # shard-tape compute overhead: interleave delay-0 vs collect
        # delay-0 isolates what the manual bucket tape costs over the
        # whole-tree value_and_grad (no injected latency on either side).
        # Since the residual-checkpointing change the tape saves every
        # layer's output and replays NO forward — this column records it.
        if r["schedule"] == "interleave" and d == 0 and tw:
            r["tape_overhead_us"] = r["us_per_step"] - tw["us_per_step"]
            r["tape_overhead_frac"] = (r["tape_overhead_us"]
                                       / tw["us_per_step"])
        name = (f"overlap/{r['net']}/N{r['workers']}/{r['schedule']}"
                f"/delay{d:.0f}")
        row(name, r["us_per_step"],
            f"exchange={r['exchange_us']:.0f}us"
            f"_roofline={r['exchange_vs_roofline']:.2f}x"
            f"_vs_collect={r['speedup_vs_collect']:.2f}x")
    return {"runs": runs, "forced_devices": SCALING_DEVICES,
            "note": "layerwise bsp+SGD worker path; exchange_us = "
                    "us_per_step minus the same schedule's delay-0 cell; "
                    "predicted_exchange_us = compiled-HLO effective "
                    "collective bytes x injected delay (core/roofline.py "
                    "convention); interleave hides the charge behind the "
                    "remaining backward walk, collect takes it "
                    "synchronously; tape_overhead_us (delay-0 interleave "
                    "rows) = saved-activation bucket tape vs whole-tree "
                    "value_and_grad at zero injected latency"}


# ---------------------------------------------------------------------------
# Roofline table from the dry-run results (deliverable g summary)
# ---------------------------------------------------------------------------
def bench_roofline(quick=False):
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_results.json")
    if not os.path.exists(path):
        row("roofline/missing", 0.0, "run_repro.launch.dryrun_--all_first")
        return
    with open(path) as f:
        results = json.load(f)
    for r in results:
        if r.get("tier") != "roofline" or r.get("status") != "ok":
            continue
        rl = r["roofline"]
        row(f"roofline/{r['arch']}/{r['shape']}",
            rl["bound_s"] * 1e6,
            f"dom={rl['dominant']}_c={rl['compute_s']:.3f}s"
            f"_m={rl['memory_s']:.3f}s_x={rl['collective_s']:.3f}s"
            f"_useful={rl['useful_flops_ratio']:.2f}")


# ---------------------------------------------------------------------------
# Serving: continuous batching under a Poisson trace (DESIGN.md §9)
# ---------------------------------------------------------------------------
def bench_serve(quick=False):
    import subprocess
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.serve"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, stdout=subprocess.PIPE, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=7200)
    if out.returncode != 0:
        raise RuntimeError(f"benchmarks.serve failed rc={out.returncode}")
    doc = json.loads(out.stdout)
    for r in doc["runs"]:
        sampling = r.get("sampling", "greedy")
        name = (f"serve/{r['arch']}/{r['mode']}"
                + (f"/{sampling}" if sampling != "greedy" else ""))
        row(name,
            r["p50_token_latency_s"] * 1e6,
            f"tok_per_s={r['tokens_per_s']:.1f}"
            f"_p99_ms={r['p99_token_latency_s'] * 1e3:.1f}"
            + (f"_speedup_vs_loop=x{r['prefill_speedup_vs_loop']:.2f}"
               if "prefill_speedup_vs_loop" in r else "")
            + (f"_vs_greedy=x{r['sampling_overhead_vs_greedy']:.2f}"
               if "sampling_overhead_vs_greedy" in r else ""))
    for arch, rl in doc.get("roofline", {}).items():
        row(f"serve/{arch}/roofline", rl["decode_bound_s"] * 1e6,
            f"dom={rl['dominant']}_measured_over_bound="
            f"{rl['measured_over_bound']:.0f}x")
    return doc


def _write_section_json(out_dir, section, rows, extra, quick):
    payload = {
        "section": section,
        "backend": jax.default_backend(),
        "quick": bool(quick),
        "timestamp": time.time(),
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }
    if isinstance(extra, dict):
        payload.update(extra)
    path = os.path.join(out_dir, f"BENCH_{section}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path}", flush=True)


#: section registry, in run order; ``--only`` choices and help text derive
#: from it, so a new ``bench_<name>`` only needs one entry here.  Each
#: section writes ``BENCH_<name>.json``.
SECTIONS = {
    "layer_times": bench_layer_times,
    "perf_model": bench_perf_model,
    "sync_modes": bench_sync_modes,
    "kernels": bench_kernels,
    "train": bench_train,
    "scaling": bench_scaling,
    "staleness": bench_staleness,
    "overlap": bench_overlap,
    "elastic": bench_elastic,
    "roofline": bench_roofline,
    "serve": bench_serve,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=sorted(SECTIONS),
                    metavar="SECTION",
                    help=f"run one section (default: all, in registry "
                         f"order) — {', '.join(SECTIONS)}")
    ap.add_argument("--out",
                    default=os.path.normpath(
                        os.path.join(os.path.dirname(__file__), "..")),
                    help="directory for the BENCH_<section>.json artifacts")
    ap.add_argument("--trace-out", default=None,
                    help="attach the obs tracer (DESIGN.md §11) and write a "
                         "Perfetto trace.json of the harness run here: one "
                         "span per section plus whatever in-process cells "
                         "emit (subprocess grids trace separately)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        from repro.obs import trace as obs_trace
        tracer = Tracer("bench")
        obs_trace.set_tracer(tracer)
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if args.only and name != args.only:
            continue
        start = len(ROWS)
        try:
            if tracer is not None:
                with tracer.span(f"section/{name}", quick=bool(args.quick)):
                    extra = fn(quick=args.quick)
            else:
                extra = fn(quick=args.quick)
        except Exception as e:  # keep the harness robust
            extra = {"error": repr(e)[:500]}
            row(f"{name}/ERROR", 0.0, repr(e)[:120])
        _write_section_json(args.out, name, ROWS[start:], extra, args.quick)
    if tracer is not None:
        from repro.obs import trace as obs_trace
        obs_trace.set_tracer(None)
        tracer.write(args.trace_out)


if __name__ == "__main__":
    main()
