"""Measured CHAOS worker-scaling study (the paper's Result 3 / Tables 7-9).

Runs the worker-mesh superstep path end-to-end for the three Table-2 nets
x sync modes x worker counts x Pallas kernels on/off, and prints one JSON
document (stdout) with steps/sec per cell; progress goes to stderr.

MUST run with enough visible devices for the largest worker count — the
parent (``benchmarks/run.py --only scaling``) spawns this module with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so meshes of
1/2/4/8 workers can all be built from one process (``make_host_mesh(n)``
takes the first n devices).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.scaling [--quick]

NOTE on absolute numbers: forced host devices all share the same CPU, so
measured "speedup" here validates the *harness and semantics* (and the
overhead trend); the paper-shaped scaling curve comes from real parallel
hardware, which this same code path targets unchanged.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

BATCH = 8          # global batch, fixed across worker counts (strong scaling)
SUPERSTEP = 4      # K steps per dispatch
DATASET = 512
LOCAL_STEPS = 4    # localsgd boundary


def build_worker_cell(cfg, sync, n_workers: int, opt, *,
                      dataset: int = DATASET, batch: int = BATCH):
    """Shared benchmark-cell setup for the worker-mesh studies (this module
    and ``benchmarks/staleness.py``): worker config + mesh + shared-queue
    pipeline + compiled worker superstep + initial state."""
    from repro.core.types import WorkerConfig
    from repro.data.mnist import make_dataset
    from repro.data.pipeline import ImagePipeline
    from repro.launch.mesh import make_host_mesh
    from repro.train.step import init_worker_state, make_worker_superstep

    worker = WorkerConfig(workers=n_workers)
    worker.validate_batch(batch)
    mesh = make_host_mesh(n_workers)
    super_fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
    imgs, labels = make_dataset(dataset, seed=0)
    pipe = ImagePipeline(imgs, labels, batch=batch, sample_mode="queue")
    state = init_worker_state(cfg, jax.random.key(0), sync, worker, opt)
    return worker, mesh, pipe, super_fn, state, (imgs, labels)


def timed_supersteps(super_fn, state, pipe, mesh, worker, n_supersteps: int,
                     k: int = SUPERSTEP):
    """Run ``n_supersteps + 1`` supersteps (first = compile, untimed) and
    return ``(state, last_metrics, us_per_step)``.

    Host batch build + device placement happen OUTSIDE the timed window:
    the driver's PrefetchFeed overlaps them with the previous superstep's
    compute, so timing them here would bias speedups against higher worker
    counts (the serialized host work doesn't shrink with N).  Each timed
    window is one dispatch + ONE host sync on the (K,) loss vector."""
    from repro.launch.train import put_worker_sharded

    batches = [put_worker_sharded(pipe, i * k, k, mesh, worker)
               for i in range(n_supersteps + 1)]
    measured_steps, elapsed, metrics = 0, 0.0, None
    for i, batch in enumerate(batches):
        t0 = time.perf_counter()
        state, metrics = super_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if i > 0:  # first dispatch = compile, not timed
            elapsed += dt
            measured_steps += k
    return state, metrics, elapsed / measured_steps * 1e6


def measure(net: str, mode: str, n_workers: int, use_kernel: bool,
            measured_supersteps: int) -> dict:
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.train.step import make_optimizer

    cfg = C.get(net)
    if use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=True)
    # staleness picks chaos' τ (1 = the paper's default) but ALSO localsgd's
    # τ-ring depth since the overlap PR; these rows measure the classic
    # blocking boundary average, so pin localsgd to τ=0 explicitly
    sync = SyncConfig(mode, local_steps=LOCAL_STEPS, axis_name="workers",
                      staleness=0 if mode == "localsgd" else 1)
    opt = make_optimizer(cfg, total_steps=4096)
    worker, mesh, pipe, super_fn, state, _ = build_worker_cell(
        cfg, sync, n_workers, opt)
    state, metrics, us_per_step = timed_supersteps(
        super_fn, state, pipe, mesh, worker, measured_supersteps)
    loss = float(np.asarray(metrics["loss"])[-1])
    return {
        "net": net, "mode": mode, "workers": n_workers,
        "use_kernel": use_kernel, "superstep": SUPERSTEP, "batch": BATCH,
        "logical_shards": worker.logical_shards,
        "us_per_step": us_per_step, "steps_per_s": 1e6 / us_per_step,
        "measured_steps": measured_supersteps * SUPERSTEP,
        "final_loss": loss,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: chaos-small, workers {1,4}, kernels "
                         "off, one measured superstep per mode")
    ap.add_argument("--modes", default="bsp,chaos,localsgd",
                    help="comma-separated sync-mode subset — re-measure "
                         "only some BENCH_scaling rows (e.g. --modes chaos "
                         "after a sync-engine change), then merge the "
                         "stdout JSON into the artifact with "
                         "benchmarks/merge_scaling.py")
    args = ap.parse_args()
    modes = tuple(m for m in args.modes.split(",") if m)

    if args.quick:
        nets = ["chaos-small"]
        worker_counts = [1, 4]
        kernel_modes = [False]
    else:
        nets = ["chaos-small", "chaos-medium", "chaos-large"]
        worker_counts = [1, 2, 4, 8]
        kernel_modes = [False, True]
    # measured supersteps per cell, scaled to per-step cost (the K-step
    # superstep amortization already smooths dispatch noise)
    net_measured = {"chaos-small": 4, "chaos-medium": 2, "chaos-large": 1}

    n_dev = len(jax.devices())
    if max(worker_counts) > n_dev:
        print(f"error: need {max(worker_counts)} devices, have {n_dev}; "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{max(worker_counts)}", file=sys.stderr)
        sys.exit(2)

    if True in kernel_modes:
        # populate the per-shard autotune keys (batch/logical_shards = 1)
        # the sharded kernel path looks up at EVERY worker count (the
        # worker route always runs kernels at per-shard batch, N=1 included)
        import repro.configs as C
        from repro.core.types import WorkerConfig
        from repro.kernels import autotune as AT
        shard_batch = BATCH // WorkerConfig().logical_shards
        for net in nets:
            print(f"# tuning per-shard kernels for {net} "
                  f"(batch {shard_batch})", file=sys.stderr, flush=True)
            AT.tune_cnn_net(C.get(net), shard_batch, iters=1)

    runs = []
    for net in nets:
        for use_kernel in kernel_modes:
            for mode in modes:
                for n in worker_counts:
                    m = 1 if args.quick else net_measured[net]
                    if use_kernel:
                        m = min(m, 2)
                    r = measure(net, mode, n, use_kernel, m)
                    runs.append(r)
                    print(f"# {net} {mode} kernel={int(use_kernel)} "
                          f"N={n}: {r['steps_per_s']:.2f} steps/s",
                          file=sys.stderr, flush=True)
    json.dump({"runs": runs}, sys.stdout)
    print(flush=True)


if __name__ == "__main__":
    main()
