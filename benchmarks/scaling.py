"""Measured CHAOS worker-scaling study (the paper's Result 3 / Tables 7-9).

Runs the worker-mesh superstep path end-to-end for the three Table-2 nets
x sync modes x worker counts x Pallas kernels on/off, and prints one JSON
document (stdout) with steps/sec per cell; progress goes to stderr.

MUST run with enough visible devices for the largest worker count — the
parent (``benchmarks/run.py --only scaling``) spawns this module with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so meshes of
1/2/4/8 workers can all be built from one process (``make_host_mesh(n)``
takes the first n devices).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.scaling [--quick]

NOTE on absolute numbers: forced host devices all share the same CPU, so
measured "speedup" here validates the *harness and semantics* (and the
overhead trend); the paper-shaped scaling curve comes from real parallel
hardware, which this same code path targets unchanged.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

BATCH = 8          # global batch, fixed across worker counts (strong scaling)
SUPERSTEP = 4      # K steps per dispatch
DATASET = 512
LOCAL_STEPS = 4    # localsgd boundary

# dense-LM column (DESIGN.md §10): per-shard batch 1 at seq 512 — long
# enough that the tuned Pallas flash forward beats the jnp blockwise path
# (the kernel's win is quadratic-in-T score traffic; below ~512 the
# interpret-mode launch overhead eats it)
LM_SEQ = 512
LM_BATCH = 4
LM_SHARDS = 4      # logical shards (so any worker count dividing 4 works)
LM_WORKERS = [1, 2, 4]
LM_MODES = ("bsp", "chaos")


def build_worker_cell(cfg, sync, n_workers: int, opt, *,
                      dataset: int = DATASET, batch: int = BATCH,
                      logical_shards: int | None = None, seq: int = LM_SEQ):
    """Shared benchmark-cell setup for the worker-mesh studies (this module
    and ``benchmarks/staleness.py``): worker config + mesh + pipeline +
    compiled worker superstep + initial state.  The pipeline dispatches on
    the config family: CNNs get the shared-queue image pipeline (and the
    eval arrays back), token families the deterministic synthetic-bigram
    ``TokenPipeline`` (eval batches are re-derived from it, so the last
    return is ``None``)."""
    from repro.core.types import WorkerConfig
    from repro.launch.mesh import make_host_mesh
    from repro.train.step import init_worker_state, make_worker_superstep

    worker = WorkerConfig(workers=n_workers,
                          logical_shards=logical_shards or 8)
    worker.validate_batch(batch)
    mesh = make_host_mesh(n_workers)
    super_fn = make_worker_superstep(cfg, sync, worker, mesh, opt)
    if cfg.family == "cnn":
        from repro.data.mnist import make_dataset
        from repro.data.pipeline import ImagePipeline
        imgs, labels = make_dataset(dataset, seed=0)
        pipe = ImagePipeline(imgs, labels, batch=batch,
                             sample_mode="queue")
        eval_data = (imgs, labels)
    else:
        from repro.data.pipeline import TokenPipeline
        pipe = TokenPipeline(cfg.vocab_size, batch, seq)
        eval_data = None
    state = init_worker_state(cfg, jax.random.key(0), sync, worker, opt)
    return worker, mesh, pipe, super_fn, state, eval_data


def timed_supersteps(super_fn, state, pipe, mesh, worker, n_supersteps: int,
                     k: int = SUPERSTEP, warmup: int = 2):
    """Run ``n_supersteps + warmup`` supersteps (the first ``warmup``
    untimed) and return ``(state, last_metrics, us_per_step)``.

    ``warmup`` defaults to 2, not 1: the first dispatch compiles, but on
    the forced-host-device mesh the SECOND dispatch still pays one-time
    work (donated-buffer layout + XLA:CPU's deferred first-execution
    passes) and lands 4-5x above steady state.  Timing it poisons short
    cells badly enough to invert real orderings — the Pallas flash cells
    compile longer, so with warmup=1 kernel-on measured SLOWER per step
    than kernel-off even though its steady-state step is faster.

    Host batch build + device placement happen OUTSIDE the timed window:
    the driver's PrefetchFeed overlaps them with the previous superstep's
    compute, so timing them here would bias speedups against higher worker
    counts (the serialized host work doesn't shrink with N).  Each timed
    window is one dispatch + ONE host sync on the (K,) loss vector."""
    from repro.launch.train import put_worker_sharded

    batches = [put_worker_sharded(pipe, i * k, k, mesh, worker)
               for i in range(n_supersteps + warmup)]
    measured_steps, elapsed, metrics = 0, 0.0, None
    for i, batch in enumerate(batches):
        t0 = time.perf_counter()
        state, metrics = super_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if i >= warmup:
            elapsed += dt
            measured_steps += k
    return state, metrics, elapsed / measured_steps * 1e6


def measure(net: str, mode: str, n_workers: int, use_kernel: bool,
            measured_supersteps: int) -> dict:
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.train.step import make_optimizer

    cfg = C.get(net)
    lm = cfg.family != "cnn"
    if use_kernel:
        cfg = dataclasses.replace(cfg, use_kernel=True)
    # staleness picks chaos' τ (1 = the paper's default) but ALSO localsgd's
    # τ-ring depth since the overlap PR; these rows measure the classic
    # blocking boundary average, so pin localsgd to τ=0 explicitly
    sync = SyncConfig(mode, local_steps=LOCAL_STEPS, axis_name="workers",
                      staleness=0 if mode == "localsgd" else 1)
    opt = make_optimizer(cfg, total_steps=4096)
    batch = LM_BATCH if lm else BATCH
    worker, mesh, pipe, super_fn, state, _ = build_worker_cell(
        cfg, sync, n_workers, opt, batch=batch,
        logical_shards=LM_SHARDS if lm else None)
    state, metrics, us_per_step = timed_supersteps(
        super_fn, state, pipe, mesh, worker, measured_supersteps)
    loss = float(np.asarray(metrics["loss"])[-1])
    r = {
        "net": net, "mode": mode, "workers": n_workers,
        "use_kernel": use_kernel, "superstep": SUPERSTEP, "batch": batch,
        "logical_shards": worker.logical_shards,
        "us_per_step": us_per_step, "steps_per_s": 1e6 / us_per_step,
        "measured_steps": measured_supersteps * SUPERSTEP,
        "final_loss": loss,
    }
    if lm:
        from repro.core.perf_model import dense_lm_ops
        ops = dense_lm_ops(cfg, LM_SEQ)
        r.update(seq=LM_SEQ, lm_fprop=ops["fprop"], lm_bprop=ops["bprop"])
    return r


def kernel_path_ok():
    """Probe the Pallas interpret path with one tiny launch: on hosts
    where ``jax.experimental.pallas`` is missing or broken the kernel
    cells skip with a stderr note instead of failing the whole grid."""
    try:
        from repro.kernels import ops as kops
        import jax.numpy as jnp
        jax.block_until_ready(kops.conv2d_valid(
            jnp.zeros((1, 6, 6, 1), jnp.float32),
            jnp.zeros((3, 3, 1, 2), jnp.float32)))
        return True, ""
    except Exception as e:  # noqa: BLE001 — any failure means "skip"
        return False, repr(e)[:200]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: chaos-small workers {1,4} kernels off, "
                         "plus one lm-bench chaos cell (kernel on + off), "
                         "one measured superstep per cell")
    ap.add_argument("--modes", default="bsp,chaos,localsgd",
                    help="comma-separated sync-mode subset — re-measure "
                         "only some BENCH_scaling rows (e.g. --modes chaos "
                         "after a sync-engine change), then merge the "
                         "stdout JSON into the artifact with "
                         "benchmarks/merge_scaling.py")
    ap.add_argument("--nets", default=None,
                    help="comma-separated net subset (e.g. --nets lm-bench "
                         "to add/refresh only the dense-LM column, merged "
                         "with benchmarks/merge_scaling.py)")
    args = ap.parse_args()
    modes = tuple(m for m in args.modes.split(",") if m)

    if args.quick:
        nets = ["chaos-small", "lm-bench"]
        worker_counts = {"chaos-small": [1, 4], "lm-bench": [2]}
        kernel_modes = {"chaos-small": [False], "lm-bench": [False, True]}
        lm_modes = ("chaos",)
    else:
        nets = ["chaos-small", "chaos-medium", "chaos-large", "lm-bench"]
        worker_counts = {net: [1, 2, 4, 8] for net in nets}
        worker_counts["lm-bench"] = list(LM_WORKERS)
        kernel_modes = {net: [False, True] for net in nets}
        lm_modes = LM_MODES
    if args.nets:
        keep = {n for n in args.nets.split(",") if n}
        nets = [n for n in nets if n in keep]
    # measured supersteps per cell, scaled to per-step cost (the K-step
    # superstep amortization already smooths dispatch noise)
    net_measured = {"chaos-small": 4, "chaos-medium": 2, "chaos-large": 1,
                    "lm-bench": 4}

    n_dev = len(jax.devices())
    need = max(max(worker_counts[n]) for n in nets)
    if need > n_dev:
        print(f"error: need {need} devices, have {n_dev}; "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{need}", file=sys.stderr)
        sys.exit(2)

    if any(True in kernel_modes[n] for n in nets):
        ok, why = kernel_path_ok()
        if not ok:
            print(f"# kernel path unavailable ({why}); dropping kernel "
                  f"cells — XLA rows still measured", file=sys.stderr,
                  flush=True)
            kernel_modes = {n: [False] for n in nets}

    if any(True in kernel_modes[n] for n in nets):
        # populate the per-shard autotune keys (batch/logical_shards = 1)
        # the sharded kernel path looks up at EVERY worker count (the
        # worker route always runs kernels at per-shard batch, N=1 included)
        import repro.configs as C
        from repro.core.types import WorkerConfig
        from repro.kernels import autotune as AT
        for net in nets:
            if True not in kernel_modes[net]:
                continue
            cfg = C.get(net)
            if cfg.family == "cnn":
                shard_batch = BATCH // WorkerConfig().logical_shards
                print(f"# tuning per-shard kernels for {net} "
                      f"(batch {shard_batch})", file=sys.stderr, flush=True)
                AT.tune_cnn_net(cfg, shard_batch, iters=1)
            else:
                shard_batch = LM_BATCH // LM_SHARDS
                print(f"# tuning per-shard flash attention for {net} "
                      f"(batch {shard_batch}, seq {LM_SEQ})",
                      file=sys.stderr, flush=True)
                AT.tune_lm_attention(cfg, shard_batch, LM_SEQ, iters=1)

    runs = []
    for net in nets:
        for use_kernel in kernel_modes[net]:
            for mode in modes:
                if net == "lm-bench" and mode not in lm_modes:
                    continue
                for n in worker_counts[net]:
                    m = 1 if args.quick else net_measured[net]
                    if use_kernel and net != "lm-bench":
                        # interpret-mode CNN kernels are 10-100x the XLA
                        # step; the flash LM step is cheap — keep its full
                        # measured window (short windows are noise-bound)
                        m = min(m, 2)
                    r = measure(net, mode, n, use_kernel, m)
                    runs.append(r)
                    print(f"# {net} {mode} kernel={int(use_kernel)} "
                          f"N={n}: {r['steps_per_s']:.2f} steps/s",
                          file=sys.stderr, flush=True)
    json.dump({"runs": runs}, sys.stdout)
    print(flush=True)


if __name__ == "__main__":
    main()
