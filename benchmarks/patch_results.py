"""Merge re-run cell results into dryrun_results.json (used after fixing a
cell, e.g. the zamba2 SSD chunk-size memory fix)."""
import json
import sys


def main(main_path, patch_path):
    with open(main_path) as f:
        results = json.load(f)
    with open(patch_path) as f:
        patches = json.load(f)
    for p in patches:
        key = (p["arch"], p["shape"], p.get("tier", "production"),
               p.get("mesh"))
        replaced = False
        for i, r in enumerate(results):
            rkey = (r["arch"], r["shape"], r.get("tier", "production"),
                    r.get("mesh"))
            if rkey == key:
                results[i] = p
                replaced = True
                break
        if not replaced:
            results.append(p)
        print("patched" if replaced else "appended", key)
    with open(main_path, "w") as f:
        json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2])
