"""Elastic-resize latency study (DESIGN.md §7): what does a membership
change cost, and does throughput recover?

For each transition of a 4 -> 2 -> 4 worker schedule (shrink, grow) the
``ResizeController`` re-slots the live state and rebuilds the compiled
superstep; this module measures

  - resize latency (seconds from membership event to first new-mesh
    dispatch being possible, as reported by ``ResizeOutcome.latency_s``,
    plus the first post-resize superstep separately — that one carries the
    recompile);
  - steady-state steps/sec before and after the transition;

and additionally times the checkpoint-restore rung (the same 4 -> 2
transition forced through rung 2 with an injected resize poison) so the
ladder's two recovery paths are directly comparable.

Prints one JSON document {"runs": [...]} to stdout; progress lines go to
stderr.  Spawned by ``benchmarks/run.py --only elastic`` with 8 forced
host devices (same harness note as benchmarks/scaling.py: forced host
devices share one CPU, so steps/sec validates the path and the overhead
trend, not real-hardware scaling).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.elastic [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

BATCH = 8
SUPERSTEP = 2
LOGICAL_SHARDS = 8


def _log(msg):
    print(msg, file=sys.stderr, flush=True)


def _steps_per_s(fn, state, pipe, mesh, worker, start, n_supersteps):
    from repro.launch.train import put_worker_sharded
    s = start
    # two untimed dispatches: the first pays compile, the second the
    # donated-buffer re-trace (same warmup the watchdog applies)
    for _ in range(2):
        state, _ = fn(state, put_worker_sharded(pipe, s, SUPERSTEP, mesh,
                                                worker))
        s += SUPERSTEP
    t0 = time.perf_counter()
    for _ in range(n_supersteps):
        batch = put_worker_sharded(pipe, s, SUPERSTEP, mesh, worker)
        state, m = fn(state, batch)
        s += SUPERSTEP
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    return state, s, (n_supersteps * SUPERSTEP) / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    n_meas = 3 if args.quick else 10

    # the parent parses this process's ENTIRE stdout as one JSON document,
    # but the ResizeController/CheckpointManager narrate to stdout — route
    # everything through stderr and keep the real stdout for the payload
    payload_out = sys.stdout
    sys.stdout = sys.stderr

    import repro.configs as C
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.chaos import SyncConfig
    from repro.core.types import WorkerConfig
    from repro.launch.elastic import ResizeController
    from repro.launch.faults import FaultPlan
    from repro.launch.mesh import make_host_mesh
    from repro.train.step import (init_worker_state, make_optimizer,
                                  make_worker_superstep)
    from benchmarks.scaling import build_worker_cell

    cfg = C.get("chaos-small")
    sync = SyncConfig("bsp", axis_name="workers")
    opt = make_optimizer(cfg, total_steps=512)
    runs = []

    def transitions(schedule, label, fault=None, ckpt_dir=None):
        worker, mesh, pipe, fn, state, _ = build_worker_cell(
            cfg, sync, schedule[0], opt, batch=BATCH)
        ctl = ResizeController(cfg, sync, opt, worker, mesh, fault=fault)
        if ckpt_dir:
            ctl.ckpt_mgr = CheckpointManager(ckpt_dir)
        s = 0
        state, s, sps = _steps_per_s(fn, state, pipe, mesh, worker, s, n_meas)
        for target in schedule[1:]:
            if ctl.ckpt_mgr is not None:
                ctl.ckpt_mgr.save(s, state)
            before = sps
            _log(f"[elastic-bench] {label}: {ctl.worker.workers} -> "
                 f"{target} at step {s} ({before:.1f} steps/s before)")
            state, new_fn, out = ctl.resize(state, target, s)
            if new_fn is None:
                _log(f"[elastic-bench] {label}: resize degraded: "
                     f"{out.detail}")
                runs.append({**out.as_dict(), "label": label,
                             "steps_per_s_before": before,
                             "steps_per_s_after": float("nan"),
                             "first_superstep_s": float("nan")})
                continue
            fn = new_fn
            if out.restart_step is not None:
                s = out.restart_step
            # the first post-resize dispatch pays the recompile — report it
            # apart from both the re-slot latency and steady-state rate
            from repro.launch.train import put_worker_sharded
            t0 = time.perf_counter()
            state, m = fn(state, put_worker_sharded(
                pipe, s, SUPERSTEP, ctl.mesh, ctl.worker))
            jax.block_until_ready(m["loss"])
            first = time.perf_counter() - t0
            s += SUPERSTEP
            state, s, sps = _steps_per_s(fn, state, pipe, ctl.mesh,
                                         ctl.worker, s, n_meas)
            runs.append({**out.as_dict(), "label": label,
                         "steps_per_s_before": before,
                         "steps_per_s_after": sps,
                         "first_superstep_s": first})
            _log(f"[elastic-bench] {label}: {out.path} in "
                 f"{out.latency_s * 1e3:.0f}ms, first superstep "
                 f"{first * 1e3:.0f}ms, {sps:.1f} steps/s after")

    # the in-memory rung: shrink then grow back
    transitions([4, 2, 4], "in-memory")
    # the checkpoint-restore rung: same shrink forced off rung 1
    with tempfile.TemporaryDirectory() as d:
        transitions([4, 2], "ckpt-restore",
                    fault=FaultPlan.from_spec("resizefail@0"), ckpt_dir=d)

    print(json.dumps({"runs": runs, "batch": BATCH,
                      "superstep": SUPERSTEP,
                      "logical_shards": LOGICAL_SHARDS}),
          file=payload_out, flush=True)


if __name__ == "__main__":
    main()
