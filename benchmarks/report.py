"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
dryrun_results.json.

    PYTHONPATH=src python -m benchmarks.report [--json dryrun_results.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def fmt_bytes(b):
    return f"{b / 2**30:.2f}GiB" if b > 2**28 else f"{b / 2**20:.0f}MiB"


def render(results):
    prod = {}
    roof = {}
    for r in results:
        key = (r["arch"], r["shape"])
        if r.get("tier") == "roofline":
            roof[key] = r
        else:
            prod.setdefault(key, {})[r.get("mesh", "?")] = r

    lines = []
    lines.append("### Dry-run matrix (production programs, scan-over-layers)")
    lines.append("")
    lines.append("| arch | shape | 16x16 | 2x16x16 | peak/dev (raw CPU) |"
                 " peak/dev (TPU est.) | compile s |")
    lines.append("|---|---|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for key in sorted(prod):
        cells = prod[key]
        row = [key[0], key[1]]
        peak = tpeak = comp = "-"
        for mesh in ("16x16", "2x16x16"):
            r = cells.get(mesh) or cells.get("?")
            if r is None:
                row.append("-")
                continue
            st = r.get("status", "?")
            if st == "ok":
                row.append("ok")
                n_ok += 1
                if mesh == "16x16" and r.get("memory_analysis"):
                    ma = r["memory_analysis"]
                    peak = f"{ma['peak_per_device_gib']:.2f}"
                    tpeak = f"{ma.get('tpu_peak_estimate_gib', float('nan')):.2f}"
                    comp = f"{r.get('compile_s', 0):.0f}"
            elif st.startswith("skip"):
                row.append("skip")
                n_skip += 1
            else:
                row.append("FAIL")
                n_fail += 1
        row += [peak, tpeak, comp]
        lines.append("| " + " | ".join(str(x) for x in row) + " |")
    lines.append("")
    lines.append(f"totals: {n_ok} compiled ok, {n_skip} skipped "
                 f"(long_500k x full-attention archs, per assignment), "
                 f"{n_fail} failed.")
    lines.append("")

    lines.append("### Roofline (single-pod 16x16, per-device terms; "
                 "unrolled reduced-depth programs extrapolated to full depth)")
    lines.append("")
    lines.append("| arch | shape | compute s | memory s | collective s "
                 "(CPU-f32 / TPU-bf16) | dominant (TPU) | "
                 "MODEL_FLOPS/HLO_FLOPs | bottleneck note |")
    lines.append("|---|---|---|---|---|---|---|---|")
    NOTES = {
        "memory": "unfused attention score traffic + remat reads -> "
                  "Pallas flash kernel (see §Perf)",
        "collective": "FSDP weight gathers + grad reduce-scatter -> "
                      "CHAOS delayed overlap / bf16 compression (see §Perf)",
        "compute": "MXU-bound — good; raise arithmetic intensity only",
    }
    for key in sorted(roof):
        r = roof[key]
        if r.get("status") != "ok":
            if str(r.get("status", "")).startswith("skip"):
                lines.append(f"| {key[0]} | {key[1]} | - | - | - | skip | - |"
                             f" {r['status'][:60]} |")
            continue
        rl = r["roofline"]
        # XLA-CPU promotes every communicated bf16 tensor to f32; all
        # tensors this framework communicates are bf16 by design -> /2
        x_tpu = rl["collective_s"] / 2
        terms = {"compute": rl["compute_s"], "memory": rl["memory_s"],
                 "collective": x_tpu}
        dom = max(terms, key=terms.get)
        lines.append(
            f"| {key[0]} | {key[1]} | {rl['compute_s']:.4f} | "
            f"{rl['memory_s']:.4f} | {rl['collective_s']:.4f} / "
            f"{x_tpu:.4f} | **{dom}** | {rl['useful_flops_ratio']:.2f} | "
            f"{NOTES[dom]} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "..", "dryrun_results.json"))
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    print(render(results))


if __name__ == "__main__":
    main()
