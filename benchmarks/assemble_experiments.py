"""Inject the generated dry-run/roofline tables into EXPERIMENTS.md at the
<!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE --> markers."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import json

from benchmarks.report import render


def main():
    root = os.path.join(os.path.dirname(__file__), "..")
    with open(os.path.join(root, "dryrun_results.json")) as f:
        results = json.load(f)
    text = render(results)
    dry, roof = text.split("### Roofline")
    roof = "### Roofline" + roof

    path = os.path.join(root, "EXPERIMENTS.md")
    with open(path) as f:
        md = f.read()
    md = md.replace("<!-- DRYRUN_TABLE -->", dry.strip())
    md = md.replace("<!-- ROOFLINE_TABLE -->", roof.strip())
    with open(path, "w") as f:
        f.write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
