"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> validate,
for the three selected cells.

Each variant re-lowers the REAL program (roofline tier: unrolled reduced
depth, extrapolated) and records the three roofline terms; the flash-kernel
variant additionally applies the documented analytic VMEM-fusion adjustment
(core/roofline.py) because XLA cost analysis cannot see inside pallas_call.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen3-moe-235b-a22b:train_4k
    PYTHONPATH=src python -m benchmarks.hillclimb --all --out hillclimb_results.json
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# must come before jax init (dryrun sets the 512-device flag on import)
from repro.launch import dryrun as DR          # noqa: E402
import repro.configs as C                       # noqa: E402
from repro.core import roofline as RL           # noqa: E402
from repro.core.types import SHAPES              # noqa: E402

CELLS = [
    ("qwen3-moe-235b-a22b", "train_4k"),   # paper-representative: CHAOS grad exchange at max scale
    ("minicpm3-4b", "train_4k"),           # most collective-bound train cell
    ("qwen3-14b", "decode_32k"),           # collective-bound serving cell
]

WS_RULES = {  # weight-stationary decode: contraction dims on `model`
              # (per-layer activation psum instead of weight all-gather);
              # `tp` output dims go replicated to avoid duplicate-axis specs
    "dp": ("pod", "data"),
    "fsdp": "model",
    "tp": None,
    "ep": "model",
    "sp": "model",
    "dpsp": ("pod", "data", "model"),
}


def terms_of(info):
    r = info["roofline"]
    return dict(c=r["compute_s"], m=r["memory_s"], x=r["collective_s"],
                dominant=r["dominant"],
                coll_bytes=r.get("collective_bytes_per_dev", 0))


def apply_flash_kernel_adjustment(info, arch, shape_name):
    """H1: substitute the validated Pallas flash kernel for the jnp
    attention — measured baseline minus analytic score-traffic overhead."""
    cfg = C.get(arch)
    shape = SHAPES[shape_name]
    n_dev = info["n_devices"]
    train = shape.kind == "train"
    d_bytes, d_flops = RL.unfused_attention_overhead(cfg, shape, n_dev, train)
    r = dict(info["roofline"])
    r["bytes_per_dev"] = max(r["bytes_per_dev"] - d_bytes, 0.0)
    r["flops_per_dev"] = max(r["flops_per_dev"] - d_flops, 0.0)
    r["memory_s"] = r["bytes_per_dev"] / RL.HBM_BW
    r["compute_s"] = r["flops_per_dev"] / RL.PEAK_FLOPS
    terms = {"compute": r["compute_s"], "memory": r["memory_s"],
             "collective": r["collective_s"]}
    r["dominant"] = max(terms, key=terms.get)
    r["adjustment"] = {"score_bytes_removed_per_dev": d_bytes,
                       "masked_flops_removed_per_dev": d_flops,
                       "kernel": "kernels/flash_attention.py (validated "
                                 "interpret=True, tests/test_kernels.py)"}
    out = dict(info)
    out["roofline"] = r
    return out


def chaos_exposed_collective(info, step_compute_s, step_memory_s):
    """H2: under CHAOS sync the gradient reduce-scatters feed only the NEXT
    step's update, so the latency-hiding scheduler overlaps them with the
    whole step; exposed collective = max(0, x - max(c, m))."""
    r = dict(info["roofline"])
    exposed = max(0.0, r["collective_s"] - max(step_compute_s, step_memory_s))
    r["collective_exposed_s"] = exposed
    out = dict(info)
    out["roofline"] = r
    return out


def run_cell(arch, shape_name, results):
    shape = SHAPES[shape_name]
    log = lambda *a: print(*a, flush=True)
    log(f"\n==== hillclimb {arch} x {shape_name} ====")

    # iteration 0: baseline (bsp, jnp attention, f32 grad exchange)
    base = DR.roofline_cell(arch, shape_name, verbose=False)
    results.append({"cell": f"{arch}:{shape_name}", "variant": "baseline",
                    **base})
    t0 = terms_of(base)
    log(f"  baseline             c/m/x = {t0['c']:.3f}/{t0['m']:.3f}/"
        f"{t0['x']:.3f}s dominant={t0['dominant']}")

    if shape.kind == "train":
        # H1: Pallas flash-attention kernel (memory term)
        v1 = apply_flash_kernel_adjustment(base, arch, shape_name)
        results.append({"cell": f"{arch}:{shape_name}",
                        "variant": "flash_kernel", **v1})
        t1 = terms_of(v1)
        log(f"  +flash kernel (H1)   c/m/x = {t1['c']:.3f}/{t1['m']:.3f}/"
            f"{t1['x']:.3f}s dominant={t1['dominant']}")

        # H2: CHAOS delayed sync (collective overlap) — re-lower for real
        ch = DR.roofline_cell(arch, shape_name, sync_mode="chaos",
                              verbose=False)
        ch = apply_flash_kernel_adjustment(ch, arch, shape_name)
        ch = chaos_exposed_collective(ch, ch["roofline"]["compute_s"],
                                      ch["roofline"]["memory_s"])
        results.append({"cell": f"{arch}:{shape_name}", "variant": "chaos",
                        **ch})
        t2 = terms_of(ch)
        log(f"  +CHAOS sync (H2)     c/m/x = {t2['c']:.3f}/{t2['m']:.3f}/"
            f"{t2['x']:.3f}s exposed_x="
            f"{ch['roofline']['collective_exposed_s']:.3f}s")

        # H3: bf16 gradient exchange w/ error feedback (collective bytes)
        cp = DR.roofline_cell(arch, shape_name, sync_mode="chaos",
                              compress=True, verbose=False)
        cp = apply_flash_kernel_adjustment(cp, arch, shape_name)
        cp = chaos_exposed_collective(cp, cp["roofline"]["compute_s"],
                                      cp["roofline"]["memory_s"])
        results.append({"cell": f"{arch}:{shape_name}", "variant":
                        "chaos+compress", **cp})
        t3 = terms_of(cp)
        log(f"  +bf16 grads (H3)     c/m/x = {t3['c']:.3f}/{t3['m']:.3f}/"
            f"{t3['x']:.3f}s coll_bytes {t0['coll_bytes']/1e9:.2f}->"
            f"{t3['coll_bytes']/1e9:.2f} GB/dev")

        # H5 (MoE): FSDP weight gathers repeat PER MICROBATCH — halving
        # micro_batches should cut the gather share of collective bytes
        cfg = C.get(arch)
        if cfg.micro_batches > 1:
            mb = DR.roofline_cell(arch, shape_name, sync_mode="chaos",
                                  extra_cfg={"micro_batches":
                                             cfg.micro_batches // 2},
                                  verbose=False)
            mb = apply_flash_kernel_adjustment(mb, arch, shape_name)
            mb = chaos_exposed_collective(mb, mb["roofline"]["compute_s"],
                                          mb["roofline"]["memory_s"])
            results.append({"cell": f"{arch}:{shape_name}",
                            "variant": "chaos+half_microbatches", **mb})
            t5 = terms_of(mb)
            log(f"  +mb/2 (H5)           c/m/x = {t5['c']:.3f}/"
                f"{t5['m']:.3f}/{t5['x']:.3f}s coll_bytes "
                f"{t0['coll_bytes']/1e9:.2f}->{t5['coll_bytes']/1e9:.2f} "
                f"GB/dev")
    else:
        # decode: H4 weight-stationary TP (fsdp -> model contraction psum)
        ws = DR.roofline_cell(arch, shape_name, rules=WS_RULES,
                              verbose=False)
        results.append({"cell": f"{arch}:{shape_name}",
                        "variant": "weight_stationary", **ws})
        t1 = terms_of(ws)
        log(f"  +weight-stationary   c/m/x = {t1['c']:.3f}/{t1['m']:.3f}/"
            f"{t1['x']:.3f}s dominant={t1['dominant']} "
            f"coll_bytes {t0['coll_bytes']/1e9:.3f}->"
            f"{t1['coll_bytes']/1e9:.3f} GB/dev")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=None,
                    help="arch:shape (repeatable)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = ([tuple(c.split(":")) for c in args.cell] if args.cell
             else CELLS)
    results = []
    for arch, shape in cells:
        try:
            run_cell(arch, shape, results)
        except Exception as e:
            import traceback
            print(f"FAILED {arch}:{shape}: {e}")
            results.append({"cell": f"{arch}:{shape}", "variant": "ERROR",
                            "error": traceback.format_exc()[-1500:]})
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
