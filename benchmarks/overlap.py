"""Overlap study: is the per-bucket exchange hidden behind backward compute?

The overlap harness (DESIGN.md §8) injects a deterministic per-byte latency
into every explicit worker-mesh collective (``SyncConfig.collective_delay_
ns_per_byte``, modelling an interconnect of bandwidth 1/delay) and measures
the layerwise bsp+SGD worker path under BOTH bucket-exchange schedules:

``collect``     gradients come stacked out of the per-shard ``lax.map``,
                then each bucket's ``gathered_shard_mean`` runs
                *synchronously* inside the update walk — the full
                bytes × delay charge lands on the critical path.
``interleave``  each bucket's gather is issued the moment that layer's
                gradient is produced during backprop (the shard tape); its
                deadline is slept off only where the exchanged gradient is
                consumed, so the remaining backward compute eats into the
                charge — the paper's compute/communication overlap.

Per cell the module reports the measured exchange cost (``us_per_step`` at
delay d minus the same schedule's delay-0 cell) and, for the blocking
schedule, the roofline-model prediction (``core/roofline.py::
parse_collectives`` effective bytes × delay) parsed from the compiled
superstep HLO — the cross-check that the injection charges exactly the
bytes the collective analysis says move.

Grid: Table-2 nets × workers ∈ {1, 2, 4} × delay ∈ {0} ∪ DELAYS ×
both schedules, layerwise bsp + plain SGD (the paper's update rule).
Prints one JSON document (stdout); progress goes to stderr.  Must run with
enough visible devices — the parent (``benchmarks/run.py --only overlap``)
spawns this module with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8``:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.overlap [--quick]

NOTE on the single-core host: forced host devices share one CPU, so a
*busy* collective could never show an overlap win here.  The injection is
deadline-based (``core/chaos.py``): the deadline is stamped at the
collective's issue point and only the REMAINDER is slept at the consumer,
so latency hidden behind compute shows up as a shorter residual sleep —
wall-clock-accurate overlap measurement without parallel hardware.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

BATCH = 8          # global batch (fixed logical_shards=8 micro-shards)
SUPERSTEP = 4      # K steps per dispatch

#: injected interconnect latencies, ns/byte (1/bandwidth: 50 ns/B ~ 20 GB/s,
#: 400 ns/B ~ 2.5 GB/s — a slow cluster link).  The interleaved schedule's
#: gates absorb each other's sleeps, so its added wall-clock tends to the
#: LARGEST bucket's charge while the blocking schedule pays the SUM of
#: charges; the win therefore grows linearly with delay and must clear the
#: tape's re-linearisation overhead (~15 ms/step on the forced-host mesh),
#: which at 50 ns/B it does not on the smallest net — both regimes are in
#: the grid on purpose.
DELAYS = [50.0, 400.0]
QUICK_DELAYS = [400.0]


def collective_bytes(super_fn, state, batch) -> float:
    """Roofline-model effective collective bytes per STEP: parse the
    compiled superstep HLO (the scan body holds each per-step collective
    once) with the same ``parse_collectives`` the roofline analysis uses —
    all-gathers count result bytes, all-reduces 2x."""
    from repro.core.roofline import parse_collectives

    hlo = super_fn.lower(state, batch).compile().as_text()
    return parse_collectives(hlo).effective_bytes


def measure(net: str, n_workers: int, interleave: bool, delay: float,
            measured_supersteps: int, want_bytes: bool = False) -> dict:
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.launch.train import put_worker_sharded
    from repro.train.step import make_optimizer

    from benchmarks.scaling import build_worker_cell, timed_supersteps

    cfg = C.get(net)
    sync = SyncConfig("bsp", layerwise=True, axis_name="workers",
                      collective_delay_ns_per_byte=delay,
                      interleave=interleave)
    opt = make_optimizer(cfg, total_steps=4096)
    worker, mesh, pipe, super_fn, state, _ = build_worker_cell(
        cfg, sync, n_workers, opt)
    eff_bytes = None
    if want_bytes:
        eff_bytes = collective_bytes(
            super_fn, state, put_worker_sharded(pipe, 0, SUPERSTEP, mesh,
                                                worker))
    state, _, us_per_step = timed_supersteps(
        super_fn, state, pipe, mesh, worker, measured_supersteps)
    return {
        "net": net, "workers": n_workers,
        "schedule": "interleave" if interleave else "collect",
        "delay_ns_per_byte": delay,
        "superstep": SUPERSTEP, "batch": BATCH,
        "logical_shards": worker.logical_shards,
        "us_per_step": us_per_step, "steps_per_s": 1e6 / us_per_step,
        "measured_steps": measured_supersteps * SUPERSTEP,
        "collective_bytes_per_step": eff_bytes,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: chaos-small, workers {1,2}, one delay")
    args = ap.parse_args()

    if args.quick:
        nets = ["chaos-small"]
        worker_counts = [1, 2]
        delays = QUICK_DELAYS
        net_measured = {"chaos-small": 3}
    else:
        nets = ["chaos-small", "chaos-medium", "chaos-large"]
        worker_counts = [1, 2, 4]
        delays = DELAYS
        # chaos-small's win margin at the top delay is a few ms/step, so it
        # gets the most measured supersteps to stay above host noise
        net_measured = {"chaos-small": 6, "chaos-medium": 2,
                        "chaos-large": 2}

    n_dev = len(jax.devices())
    if max(worker_counts) > n_dev:
        print(f"error: need {max(worker_counts)} devices, have {n_dev}; "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{max(worker_counts)}", file=sys.stderr)
        sys.exit(2)

    runs = []
    for net in nets:
        for n in worker_counts:
            eff = None
            for interleave in (False, True):
                # the delay-0 cell is the schedule's compute baseline; the
                # blocking schedule's cell also yields the compiled-HLO
                # collective bytes for the roofline cross-check column
                # (reused for the interleaved rows — same collectives, only
                # the issue order moves)
                base = measure(net, n, interleave, 0.0, net_measured[net],
                               want_bytes=not interleave)
                got = base.pop("collective_bytes_per_step")
                eff = got if got is not None else eff
                base["exchange_us"] = 0.0
                base["collective_bytes_per_step"] = eff
                runs.append(base)
                sched = base["schedule"]
                print(f"# {net} N={n} {sched} delay=0: "
                      f"{base['us_per_step']:.0f} us/step "
                      f"(collective_bytes={eff})",
                      file=sys.stderr, flush=True)
                for d in delays:
                    r = measure(net, n, interleave, d, net_measured[net])
                    r.pop("collective_bytes_per_step")
                    r["collective_bytes_per_step"] = eff
                    r["exchange_us"] = r["us_per_step"] - base["us_per_step"]
                    # roofline prediction of the *blocking* exchange cost:
                    # effective bytes × delay (ns -> us); the interleaved
                    # schedule should come in UNDER it by the hidden part
                    r["predicted_exchange_us"] = (
                        eff * d * 1e-3 if eff is not None else None)
                    runs.append(r)
                    print(f"# {net} N={n} {sched} delay={d:.0f}: "
                          f"{r['us_per_step']:.0f} us/step "
                          f"exchange={r['exchange_us']:.0f}us "
                          f"predicted={r['predicted_exchange_us']}",
                          file=sys.stderr, flush=True)
    json.dump({"runs": runs}, sys.stdout)
    print(flush=True)


if __name__ == "__main__":
    main()
