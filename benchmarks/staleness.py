"""Staleness-τ convergence + throughput study (paper Result 1-2 / Tables
4-6 analogue): does CHAOS's asynchrony degrade accuracy?

Runs the worker-mesh superstep path for the three Table-2 nets × chaos
staleness τ ∈ {0, 1, 2, 4} × workers ∈ {1, 4, 8}, training each cell for a
fixed number of steps and recording BOTH steps/sec and the final error
over the whole dataset — the paper's claim is that accuracy is not
significantly degraded by asynchronous (arbitrary-order, stale) weight
updates, so the artifact holds the error delta vs the τ=0 (≡ bsp) cell
next to the throughput, plus the Listing-2 performance-model speedup
prediction for the same worker count.

The **layerwise column** (``layerwise: true`` rows, τ ∈ {0, 1} × every
worker count): the same cells through the ParamBuckets per-bucket exchange
path (``--layerwise``) — each bucket runs its own ``gathered_shard_mean``
and update in reverse-production order instead of one stacked whole-tree
reduction, the paper's per-layer exchange granularity.  ``run.py`` attaches
``speedup_vs_batched`` (layerwise vs its batched twin) so the
per-layer-exchange overlap is a first-class column; layerwise τ=0 bsp is
bit-exact to batched bsp, so its error column doubles as a correctness
check.

τ=0 resolves to the bsp strategy object itself (train/sync.py), so its
cells ARE the synchronous baseline.  Must run with enough visible devices
for the largest worker count — the parent (``benchmarks/run.py --only
staleness``) spawns this module with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.staleness [--quick]

NOTE on absolute numbers: forced host devices share one CPU, so measured
throughput validates the harness + overhead trend (the τ>0 cells drop the
blocking exchange from the update's critical path; the wall-clock benefit
needs real parallel hardware, which runs this code path unchanged).  The
ERROR columns are hardware-independent and are the paper-fidelity payload.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

BATCH = 8          # global batch, fixed across worker counts (the cell
                   # setup itself lives in benchmarks/scaling.py)
SUPERSTEP = 4      # K steps per dispatch
EVAL_BATCH = 64

#: per-net (train_steps, constant lr): the paper's 1e-3 + decay schedule
#: barely moves these synthetic-MNIST runs inside a benchmark-sized step
#: budget, leaving the error at chance where τ effects are invisible — so
#: each net trains with a constant lr chosen so the τ=0 (synchronous)
#: baseline converges well below chance, and ONLY τ varies across a row.
#: Probed so τ=4 stays stable (delayed-SGD stability degrades with lr·τ).
TRAIN_STEPS = {"chaos-small": 256, "chaos-medium": 192, "chaos-large": 160,
               "lm-bench": 64}
TRAIN_LR = {"chaos-small": 0.05, "chaos-medium": 0.05, "chaos-large": 0.01,
            "lm-bench": 0.5}

# dense-LM eval set: deterministic TokenPipeline batches at a seed disjoint
# from the training stream (seed 0)
LM_EVAL_BATCHES = 8
LM_EVAL_BATCH = 16


def _final_error_tokens(cfg, params) -> dict:
    """Held-out next-token error rate + loss for the dense-LM cells: the
    synthetic-bigram token pipeline is a pure function of (seed, step), so
    seed-1 batches are a fixed eval set the training stream never saw."""
    from repro.data.pipeline import TokenPipeline
    from repro.models import lm
    from benchmarks.scaling import LM_SEQ

    pipe = TokenPipeline(cfg.vocab_size, LM_EVAL_BATCH, LM_SEQ, seed=1)

    @jax.jit
    def eval_batch(p, batch):
        loss, _ = lm.loss_fn(p, batch, cfg)
        logits, _ = lm.forward(p, batch["tokens"], cfg)
        pred = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
        err = jnp.mean((pred != batch["labels"]).astype(jnp.float32))
        return loss, err

    errs, losses = [], []
    for step in range(LM_EVAL_BATCHES):
        batch = jax.tree.map(jnp.asarray, pipe.batch_at(step))
        loss, err = eval_batch(params, batch)
        errs.append(float(err))
        losses.append(float(loss))
    return {"final_error": float(np.mean(errs)),
            "final_loss": float(np.mean(losses))}


def final_error(cfg, state, eval_data, stacked: bool) -> dict:
    """Error rate over the whole eval set at the trained weights (workers'
    mean for worker-stacked states — the shared-trajectory view).  CNN
    cells evaluate the dataset arrays returned by ``build_worker_cell``;
    token cells re-derive a held-out eval stream from the deterministic
    pipeline (``eval_data`` is None there)."""
    from repro.models.api import get_ops

    params = jax.tree.map(np.asarray, state["params"])
    if stacked:
        params = jax.tree.map(lambda x: x.mean(axis=0), params)
    if cfg.family != "cnn":
        return _final_error_tokens(cfg, params)
    imgs, labels = eval_data
    ops = get_ops(cfg)
    loss_fn = jax.jit(ops.loss)
    errs, losses = [], []
    for lo in range(0, len(imgs), EVAL_BATCH):
        batch = {"images": imgs[lo:lo + EVAL_BATCH],
                 "labels": labels[lo:lo + EVAL_BATCH]}
        loss, m = loss_fn(params, batch)
        errs.append(float(m["error_rate"]))
        losses.append(float(loss))
    return {"final_error": float(np.mean(errs)),
            "final_loss": float(np.mean(losses))}


def run_cell(net: str, tau: int, n_workers: int, train_steps: int,
             lr: float, layerwise: bool = False) -> dict:
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.optim import sgd
    from repro.train.sync import get_strategy

    import benchmarks.scaling as S
    from benchmarks.scaling import build_worker_cell, timed_supersteps

    cfg = C.get(net)
    lm = cfg.family != "cnn"
    sync = SyncConfig("chaos", staleness=tau, axis_name="workers",
                      layerwise=layerwise)
    stacked = get_strategy(sync).stacked_state
    opt = sgd(lambda s: lr)
    batch = S.LM_BATCH if lm else BATCH
    worker, mesh, pipe, super_fn, state, eval_data = build_worker_cell(
        cfg, sync, n_workers, opt, batch=batch,
        logical_shards=S.LM_SHARDS if lm else None)
    # the whole training run is the timed window (minus the two warm-up
    # dispatches — compile + first donated execution), so steps/sec and
    # the convergence payload come from the same cell
    state, _, us_per_step = timed_supersteps(
        super_fn, state, pipe, mesh, worker, train_steps // SUPERSTEP - 2)
    cell = {
        "net": net, "tau": tau, "workers": n_workers,
        "layerwise": layerwise,
        "superstep": SUPERSTEP, "batch": batch,
        "logical_shards": worker.logical_shards,
        "train_steps": train_steps, "lr": lr, "stacked_state": stacked,
        "us_per_step": us_per_step, "steps_per_s": 1e6 / us_per_step,
    }
    if lm:
        from repro.core.perf_model import dense_lm_ops
        ops = dense_lm_ops(cfg, S.LM_SEQ)
        cell.update(seq=S.LM_SEQ, lm_fprop=ops["fprop"],
                    lm_bprop=ops["bprop"])
    cell.update(final_error(cfg, state, eval_data, stacked))
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: chaos-small + chaos-medium tau {0,2} at "
                         "4 workers, plus lm-bench tau {0,1} at 2 workers "
                         "(with the layerwise tau=0 bit-identity cell), "
                         "short training")
    ap.add_argument("--nets", default=None,
                    help="comma-separated net subset (e.g. --nets lm-bench "
                         "to add/refresh only the dense-LM column, merged "
                         "with benchmarks/merge_staleness.py)")
    args = ap.parse_args()

    if args.quick:
        nets = ["chaos-small", "chaos-medium", "lm-bench"]
        net_taus = {"chaos-small": [0, 2], "chaos-medium": [0, 2],
                    "lm-bench": [0, 1]}
        net_workers = {"chaos-small": [4], "chaos-medium": [4],
                       "lm-bench": [2]}
        train_steps = {"chaos-small": 64, "chaos-medium": 32,
                       "lm-bench": 32}
        # CI layerwise cells: one CNN per-bucket-exchange point plus the
        # LM chunked-stack tau=0 cell (bit-identical to its batched twin)
        layerwise_cells = {("chaos-small", 0, 4), ("lm-bench", 0, 2)}
    else:
        cnn_nets = ["chaos-small", "chaos-medium", "chaos-large"]
        nets = cnn_nets + ["lm-bench"]
        # dense-LM cells keep tau in {0, 1}: the error-delta payload needs
        # tau=0 (sync baseline) and the paper-default tau=1; worker counts
        # must divide the LM logical-shard count (4)
        net_taus = {n: [0, 1, 2, 4] for n in cnn_nets}
        net_taus["lm-bench"] = [0, 1]
        net_workers = {n: [1, 4, 8] for n in cnn_nets}
        net_workers["lm-bench"] = [1, 2, 4]
        train_steps = dict(TRAIN_STEPS)
        # the layerwise column (per-bucket exchange + update during
        # backprop): τ ∈ {0, 1} are the canonical overlap cells — bsp-exact
        # per-bucket collectives and stale per-bucket chaos — measured at
        # every worker count next to their batched twins
        layerwise_cells = {(net, tau, n) for net in nets for tau in (0, 1)
                           for n in net_workers[net]}
    if args.nets:
        keep = {n for n in args.nets.split(",") if n}
        nets = [n for n in nets if n in keep]

    n_dev = len(jax.devices())
    need = max(max(net_workers[n]) for n in nets)
    if need > n_dev:
        print(f"error: need {need} devices, have {n_dev}; "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{need}", file=sys.stderr)
        sys.exit(2)

    runs = []
    for net in nets:
        for n in net_workers[net]:
            for tau in net_taus[net]:
                for layerwise in (False, True):
                    if layerwise and (net, tau, n) not in layerwise_cells:
                        continue
                    r = run_cell(net, tau, n, train_steps[net],
                                 TRAIN_LR[net], layerwise=layerwise)
                    runs.append(r)
                    print(f"# {net} tau={tau} N={n} "
                          f"lw={int(layerwise)}: "
                          f"{r['steps_per_s']:.2f} steps/s "
                          f"err={r['final_error']:.4f}",
                          file=sys.stderr, flush=True)
    json.dump({"runs": runs}, sys.stdout)
    print(flush=True)


if __name__ == "__main__":
    main()
