"""Staleness-τ convergence + throughput study (paper Result 1-2 / Tables
4-6 analogue): does CHAOS's asynchrony degrade accuracy?

Runs the worker-mesh superstep path for the three Table-2 nets × chaos
staleness τ ∈ {0, 1, 2, 4} × workers ∈ {1, 4, 8}, training each cell for a
fixed number of steps and recording BOTH steps/sec and the final error
over the whole dataset — the paper's claim is that accuracy is not
significantly degraded by asynchronous (arbitrary-order, stale) weight
updates, so the artifact holds the error delta vs the τ=0 (≡ bsp) cell
next to the throughput, plus the Listing-2 performance-model speedup
prediction for the same worker count.

The **layerwise column** (``layerwise: true`` rows, τ ∈ {0, 1} × every
worker count): the same cells through the ParamBuckets per-bucket exchange
path (``--layerwise``) — each bucket runs its own ``gathered_shard_mean``
and update in reverse-production order instead of one stacked whole-tree
reduction, the paper's per-layer exchange granularity.  ``run.py`` attaches
``speedup_vs_batched`` (layerwise vs its batched twin) so the
per-layer-exchange overlap is a first-class column; layerwise τ=0 bsp is
bit-exact to batched bsp, so its error column doubles as a correctness
check.

τ=0 resolves to the bsp strategy object itself (train/sync.py), so its
cells ARE the synchronous baseline.  Must run with enough visible devices
for the largest worker count — the parent (``benchmarks/run.py --only
staleness``) spawns this module with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.staleness [--quick]

NOTE on absolute numbers: forced host devices share one CPU, so measured
throughput validates the harness + overhead trend (the τ>0 cells drop the
blocking exchange from the update's critical path; the wall-clock benefit
needs real parallel hardware, which runs this code path unchanged).  The
ERROR columns are hardware-independent and are the paper-fidelity payload.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

BATCH = 8          # global batch, fixed across worker counts (the cell
                   # setup itself lives in benchmarks/scaling.py)
SUPERSTEP = 4      # K steps per dispatch
EVAL_BATCH = 64

#: per-net (train_steps, constant lr): the paper's 1e-3 + decay schedule
#: barely moves these synthetic-MNIST runs inside a benchmark-sized step
#: budget, leaving the error at chance where τ effects are invisible — so
#: each net trains with a constant lr chosen so the τ=0 (synchronous)
#: baseline converges well below chance, and ONLY τ varies across a row.
#: Probed so τ=4 stays stable (delayed-SGD stability degrades with lr·τ).
TRAIN_STEPS = {"chaos-small": 256, "chaos-medium": 192, "chaos-large": 160}
TRAIN_LR = {"chaos-small": 0.05, "chaos-medium": 0.05, "chaos-large": 0.01}


def final_error(cfg, state, imgs, labels, stacked: bool) -> dict:
    """Error rate over the whole dataset at the trained weights (workers'
    mean for worker-stacked states — the shared-trajectory view)."""
    from repro.models.api import get_ops

    params = jax.tree.map(np.asarray, state["params"])
    if stacked:
        params = jax.tree.map(lambda x: x.mean(axis=0), params)
    ops = get_ops(cfg)
    loss_fn = jax.jit(ops.loss)
    errs, losses = [], []
    for lo in range(0, len(imgs), EVAL_BATCH):
        batch = {"images": imgs[lo:lo + EVAL_BATCH],
                 "labels": labels[lo:lo + EVAL_BATCH]}
        loss, m = loss_fn(params, batch)
        errs.append(float(m["error_rate"]))
        losses.append(float(loss))
    return {"final_error": float(np.mean(errs)),
            "final_loss": float(np.mean(losses))}


def run_cell(net: str, tau: int, n_workers: int, train_steps: int,
             lr: float, layerwise: bool = False) -> dict:
    import repro.configs as C
    from repro.core.chaos import SyncConfig
    from repro.optim import sgd
    from repro.train.sync import get_strategy

    from benchmarks.scaling import build_worker_cell, timed_supersteps

    cfg = C.get(net)
    sync = SyncConfig("chaos", staleness=tau, axis_name="workers",
                      layerwise=layerwise)
    stacked = get_strategy(sync).stacked_state
    opt = sgd(lambda s: lr)
    worker, mesh, pipe, super_fn, state, (imgs, labels) = build_worker_cell(
        cfg, sync, n_workers, opt)
    # the whole training run is the timed window (minus the compile
    # dispatch), so steps/sec and the convergence payload come from the
    # same cell
    state, _, us_per_step = timed_supersteps(
        super_fn, state, pipe, mesh, worker, train_steps // SUPERSTEP - 1)
    cell = {
        "net": net, "tau": tau, "workers": n_workers,
        "layerwise": layerwise,
        "superstep": SUPERSTEP, "batch": BATCH,
        "logical_shards": worker.logical_shards,
        "train_steps": train_steps, "lr": lr, "stacked_state": stacked,
        "us_per_step": us_per_step, "steps_per_s": 1e6 / us_per_step,
    }
    cell.update(final_error(cfg, state, imgs, labels, stacked))
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: chaos-small + chaos-medium, tau {0,2}, "
                         "4 workers, short training")
    args = ap.parse_args()

    if args.quick:
        nets = ["chaos-small", "chaos-medium"]
        taus = [0, 2]
        worker_counts = [4]
        train_steps = {"chaos-small": 64, "chaos-medium": 32}
        # CI layerwise cell: one per-bucket-exchange point next to the
        # batched grid (uploaded with the quick artifact)
        layerwise_cells = {("chaos-small", 0, 4)}
    else:
        nets = ["chaos-small", "chaos-medium", "chaos-large"]
        taus = [0, 1, 2, 4]
        worker_counts = [1, 4, 8]
        train_steps = dict(TRAIN_STEPS)
        # the layerwise column (per-bucket exchange + update during
        # backprop): τ ∈ {0, 1} are the canonical overlap cells — bsp-exact
        # per-bucket collectives and stale per-bucket chaos — measured at
        # every worker count next to their batched twins
        layerwise_cells = {(net, tau, n) for net in nets for tau in (0, 1)
                           for n in worker_counts}

    n_dev = len(jax.devices())
    if max(worker_counts) > n_dev:
        print(f"error: need {max(worker_counts)} devices, have {n_dev}; "
              f"set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{max(worker_counts)}", file=sys.stderr)
        sys.exit(2)

    runs = []
    for net in nets:
        for n in worker_counts:
            for tau in taus:
                for layerwise in (False, True):
                    if layerwise and (net, tau, n) not in layerwise_cells:
                        continue
                    r = run_cell(net, tau, n, train_steps[net],
                                 TRAIN_LR[net], layerwise=layerwise)
                    runs.append(r)
                    print(f"# {net} tau={tau} N={n} "
                          f"lw={int(layerwise)}: "
                          f"{r['steps_per_s']:.2f} steps/s "
                          f"err={r['final_error']:.4f}",
                          file=sys.stderr, flush=True)
    json.dump({"runs": runs}, sys.stdout)
    print(flush=True)


if __name__ == "__main__":
    main()
